//! The event-triggered scheduler (§4.3).
//!
//! Airflow runs the scheduler as an always-on thread; sAirflow executes a
//! *single pass* of the same algorithm per FaaS invocation, triggered by
//! events (a completed task, a new DAG run, a periodic cron fire). For
//! consistency, passes are fed from a single-shard FIFO queue — the
//! serverless surrogate of Airflow's scheduler critical section.
//!
//! The pass itself is a pure function from a metadata-database snapshot
//! and an event batch to a transaction ([`scheduling_pass`]) — exactly the
//! paper's three steps:
//!
//! 1. for each DAG ready to execute: create a DAG run;
//! 2. for each task in each DAG run with all predecessors completed:
//!    create a *scheduled* task instance;
//! 3. for each scheduled task instance, label it *queued*.
//!
//! Being pure, the pass is directly property-testable (see
//! `rust/tests/prop_scheduler.rs`). The MWAA baseline reuses this exact
//! pass inside its polling loop — same Airflow semantics, different
//! triggering model.
//!
//! # Allocation-free hot path
//!
//! Every message, key and write the pass handles is keyed by the `Copy`
//! [`DagId`] symbol: the per-message work is map probes and 8-byte copies
//! — no `clone()`/`to_string()` anywhere in the loop, and every DB range
//! probe uses `Copy` bounds ([`crate::cloud::db::RunTable::of_dag`]).
//! This is what keeps a pass over a large snapshot cheap at high fan-out
//! (`bench_hotpath` cell 3), which the paper's scale-out result rests on.

use crate::cloud::db::{MetaDb, RunKey, TiRow, Txn, Write};
use crate::dag::graph::DagGraph;
use crate::dag::state::{DagId, RunState, RunType, TiState};
use crate::sim::time::SimTime;
use std::collections::{BTreeMap, BTreeSet};

/// Messages feeding the scheduler (the FIFO queue payload). All-`Copy`:
/// enqueue, redelivery and batch processing never touch the heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedMsg {
    /// A typed trigger: one launch of a workflow. `run_type` is the
    /// trigger's provenance and drives the scheduling policy — cron fires
    /// ([`RunType::Scheduled`]) are dropped while the DAG is paused or
    /// past `max_active_runs`; manual triggers are never dropped (a
    /// paused or gate-saturated DAG parks a *queued* run, Airflow
    /// parity); backfill triggers create queued runs promoted under the
    /// separate backfill budget.
    Trigger { dag_id: DagId, logical_ts: SimTime, run_type: RunType },
    /// A promotion nudge for a DAG whose parked runs may now be able to
    /// start: sent on unpause (the CDC-routed `DagPaused` edge) and after
    /// API actions that free capacity outside the event fabric
    /// (mark-terminal, delete). The pass itself carries the promotion
    /// logic; this message exists to cause one.
    DagResumed { dag_id: DagId },
    /// A DAG run row changed (e.g. the run was created).
    RunChanged { dag_id: DagId, run_id: u64 },
    /// A task instance reached a terminal-ish state
    /// (success / failed / up-for-retry).
    TaskFinished { dag_id: DagId, run_id: u64, task_id: u32, state: TiState },
}

impl SchedMsg {
    /// The DAG this message is about — every scheduler message is
    /// DAG-addressed, which is what makes the batch partitionable by
    /// control-plane shard.
    pub fn dag_id(&self) -> DagId {
        match *self {
            SchedMsg::Trigger { dag_id, .. }
            | SchedMsg::DagResumed { dag_id }
            | SchedMsg::RunChanged { dag_id, .. }
            | SchedMsg::TaskFinished { dag_id, .. } => dag_id,
        }
    }

    /// The control-plane shard that owns this message's DAG.
    pub fn shard_of(&self, n_shards: usize) -> usize {
        self.dag_id().shard_of(n_shards)
    }
}

/// Scheduler limits, matching the paper's deployment (§5): both systems
/// support at most 125 concurrent task instances.
#[derive(Debug, Clone)]
pub struct SchedLimits {
    /// Maximum queued+running task instances across all DAGs (platform
    /// capacity — the 125 worker slots are physical and shared).
    pub parallelism: usize,
    /// Default maximum backfill runs in state `Running` **per tenant**. A
    /// backfill expands a whole date range at once; without a separate
    /// budget those runs would race cron traffic for the 125 parallelism
    /// slots. Excess backfill runs wait in `Queued` and are promoted
    /// FIFO-by-arrival as earlier ones finish. A tenant record can
    /// override its own budget (`TenantRow::max_active_backfill_runs`);
    /// budgets are never shared across tenants, so one tenant's backfill
    /// storm cannot consume another tenant's promotion slots.
    pub max_active_backfill_runs: usize,
}

impl Default for SchedLimits {
    fn default() -> SchedLimits {
        SchedLimits { parallelism: 125, max_active_backfill_runs: 16 }
    }
}

/// Statistics of one pass (for reporting/tests).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct PassStats {
    pub runs_created: usize,
    /// Cron triggers skipped by the `max_active_runs` gate (manual
    /// triggers park in `Queued` instead; backfill has its own budget).
    pub runs_skipped: usize,
    /// Queued runs promoted to `Running` (backfill budget, unpause,
    /// freed `max_active_runs` capacity).
    pub runs_promoted: usize,
    /// Backfill triggers dropped because their logical date already has a
    /// run for that DAG (re-POSTed overlapping range, Airflow dedup).
    pub backfill_deduped: usize,
    pub tis_scheduled: usize,
    pub tis_queued: usize,
    pub runs_completed: usize,
    pub retries: usize,
    /// Successors dispatched directly by a worker's completion callback
    /// (docs/FASTPATH.md). Counted at the dispatch site, not here — the
    /// field lives in `PassStats` so one struct carries the whole
    /// scheduling picture into operator health.
    pub fastpath_dispatched: usize,
    /// Successors a fast-path-enabled DAG could *not* dispatch directly
    /// (ambiguous edge, paused DAG, parked run, no parallelism headroom);
    /// the normal pass handles them. Counted at the dispatch site.
    pub fastpath_fallback: usize,
    /// Fast-dispatched task instances this pass encountered and left
    /// alone: the apply-time marker proves a worker already queued them,
    /// so reconciliation is a no-op (fast-path on/off outcome parity).
    pub fastpath_reconciled_noop: usize,
}

/// Output of a scheduling pass: the transaction to commit plus statistics.
#[derive(Debug, Default)]
pub struct PassOutput {
    pub txn: Txn,
    pub stats: PassStats,
}

/// Next run id for a DAG (1-based, dense). The run table is ordered, so
/// the current maximum is the last key of the DAG's range — one `Copy`
/// range probe, not a scan.
fn next_run_id(db: &MetaDb, dag_id: DagId) -> u64 {
    db.dag_runs.of_dag(dag_id).next_back().map(|((_, r), _)| *r).unwrap_or(0) + 1
}

/// Execute one scheduling pass over a database snapshot.
///
/// `now` is the pass time (used for run start and ready computation when a
/// predecessor end time is unknown). The returned transaction must be
/// committed by the caller; because passes are serialized by the FIFO
/// feed, the snapshot cannot race with another pass.
///
/// This is the single-shard facade over [`scheduling_pass_sharded`]: at
/// `n_shards = 1` the shard loop degenerates to one iteration over the
/// whole batch, so the output transaction is byte-identical to the
/// pre-sharding pass.
pub fn scheduling_pass(
    db: &MetaDb,
    now: SimTime,
    batch: &[SchedMsg],
    limits: &SchedLimits,
) -> PassOutput {
    scheduling_pass_sharded(db, now, batch, limits, 1).pop().unwrap_or_default()
}

/// Execute one scheduling pass partitioned into `n_shards` control-plane
/// shards: element `i` of the returned vector is shard `i`'s transaction
/// and statistics, touching only rows whose `DagId` hashes to shard `i` —
/// the caller commits each shard's transaction independently, so a kill
/// between commits leaves every other shard's writes either fully applied
/// or fully absent.
///
/// The batch is partitioned *stably* (shard 0's messages in batch order,
/// then shard 1's, ...), and three pieces of budget state are deliberately
/// shared across the shard loop rather than sharded:
///
/// * the global `parallelism` limit — the 125 worker slots are physical
///   and shard-blind;
/// * per-tenant backfill budgets — a tenant's DAGs hash across shards,
///   and budgets must hold per tenant, not per (tenant, shard);
/// * the backfill promotion FIFO — drained globally by arrival sequence
///   across shards (cross-DAG, cross-shard fairness), with each
///   promotion write routed into the owning shard's transaction.
///
/// Everything else (run-id allocation, `max_active_runs` gates, dirty-run
/// scheduling, graphs, dedup probe sets) is per-DAG and therefore
/// naturally shard-confined.
pub fn scheduling_pass_sharded(
    db: &MetaDb,
    now: SimTime,
    batch: &[SchedMsg],
    limits: &SchedLimits,
    n_shards: usize,
) -> Vec<PassOutput> {
    let n = n_shards.max(1);
    let mut outs: Vec<PassOutput> = Vec::new();
    outs.resize_with(n, PassOutput::default);

    // Current global active count for the parallelism limit; queue
    // decisions anywhere in this pass immediately consume budget. Shared
    // across shards: the worker slots are physical.
    let mut active = db.active_ti_count();
    // Backfill completions this pass detects free their *tenant's* budget
    // for the global promotion step below. Shared across shards: a
    // tenant's DAGs span shards. Tenant keys are the interned `'static`
    // strings (field reads, no allocation).
    let mut backfill_freed: BTreeMap<&'static str, usize> = BTreeMap::new();
    // Backfill runs created by this pass — `(batch index, run key)` so
    // the global promotion step below considers them in true batch
    // arrival order even though the shard loop visits them shard-grouped.
    let mut created_backfill: Vec<(usize, RunKey)> = Vec::new();

    for (shard, out) in outs.iter_mut().enumerate() {
        scheduling_pass_shard(
            db,
            now,
            batch,
            limits,
            (shard, n),
            out,
            &mut active,
            &mut backfill_freed,
            &mut created_backfill,
        );
    }

    // Backfill promotion: drain queued backfill runs into `Running` while
    // their *tenant's* budget allows. Budgets are strictly per tenant
    // (record override or the deployment default) — a saturated tenant is
    // skipped, never allowed to block another tenant's promotions. Runs
    // completed by *this* pass free budget immediately (their terminal
    // write commits in this same pass's transactions), which keeps the
    // pipeline moving without routing terminal run changes back to the
    // scheduler. The snapshot queue drains FIFO by arrival sequence —
    // globally across shards (cross-DAG, cross-shard fairness) — then
    // the runs created above in batch order; each promotion write is
    // routed into the transaction of the shard that owns its DAG.
    fn bf_budget_left(
        db: &MetaDb,
        limits: &SchedLimits,
        freed: &BTreeMap<&'static str, usize>,
        tenant: &str,
    ) -> usize {
        let cap = db.backfill_cap_of(tenant, limits.max_active_backfill_runs);
        let active = db
            .active_backfill_count_of(tenant)
            .saturating_sub(freed.get(tenant).copied().unwrap_or(0));
        cap.saturating_sub(active)
    }
    let mut bf_remaining: BTreeMap<&'static str, usize> = BTreeMap::new();
    for &key in db.queued_backfill() {
        // Skip runs whose DAG vanished (the dirty loop fails them).
        if !db.serialized.contains_key(&key.0) {
            continue;
        }
        let tenant = key.0.tenant();
        let rem = bf_remaining
            .entry(tenant)
            .or_insert_with(|| bf_budget_left(db, limits, &backfill_freed, tenant));
        if *rem == 0 {
            continue; // this tenant is saturated; others still drain
        }
        *rem -= 1;
        if let Some(out) = outs.get_mut(key.0.shard_of(n)) {
            out.txn.push(Write::PromoteRun { dag_id: key.0, run_id: key.1 });
            out.stats.runs_promoted += 1;
        }
    }
    // Stable by construction *within* a shard; the sort restores global
    // batch order across shards (batch indices are unique).
    created_backfill.sort_by_key(|&(idx, _)| idx);
    for (_, (dag_id, run_id)) in created_backfill {
        let tenant = dag_id.tenant();
        let rem = bf_remaining
            .entry(tenant)
            .or_insert_with(|| bf_budget_left(db, limits, &backfill_freed, tenant));
        if *rem == 0 {
            continue;
        }
        *rem -= 1;
        if let Some(out) = outs.get_mut(dag_id.shard_of(n)) {
            out.txn.push(Write::PromoteRun { dag_id, run_id });
            out.stats.runs_promoted += 1;
        }
    }
    outs
}

/// One shard's slice of a scheduling pass: steps 1–3 of the paper's
/// algorithm plus foreground promotion, over only the messages and parked
/// runs whose DAG hashes to `shard` (of `n_shards`). Writes go to `out`;
/// `active`, `backfill_freed` and `created_backfill` are the cross-shard
/// state shared with [`scheduling_pass_sharded`]'s global promotion step.
#[allow(clippy::too_many_arguments)]
fn scheduling_pass_shard(
    db: &MetaDb,
    now: SimTime,
    batch: &[SchedMsg],
    limits: &SchedLimits,
    (shard, n_shards): (usize, usize),
    out: &mut PassOutput,
    active: &mut usize,
    backfill_freed: &mut BTreeMap<&'static str, usize>,
    created_backfill: &mut Vec<(usize, RunKey)>,
) {
    // Runs that this pass must (re)examine. `Copy` keys: inserting per
    // message copies 16 bytes, never a heap string.
    let mut dirty_runs: BTreeSet<RunKey> = BTreeSet::new();

    // Per-DAG bookkeeping shared by every trigger of this pass. The seed
    // code recomputed `next_run_id(db, ..) + already` and
    // `active_runs + already` independently per message; folding both
    // into one entry computed once per DAG makes it impossible for id
    // allocation and the `max_active_runs` gate to drift apart when a
    // batch mixes run creation with `RunChanged` events for the same DAG.
    struct PassDag {
        /// `next_run_id` from the snapshot, computed once per DAG.
        base_id: u64,
        /// Runs created by this pass, all run types (id allocation).
        created: u64,
        /// Non-backfill runs created by this pass (`max_active_runs`).
        created_fg: u64,
        /// Active non-backfill runs in the snapshot, computed once.
        snapshot_active_fg: u64,
    }
    let mut pass_dags: BTreeMap<DagId, PassDag> = BTreeMap::new();
    // Backfill dedup probe sets, one per DAG, seeded lazily from the
    // snapshot (one range scan per DAG per pass — not one per trigger)
    // and extended with the dates this pass creates, so overlapping
    // POSTs dedup whether the earlier range is already committed or
    // still in this very batch.
    let mut bf_dates: BTreeMap<DagId, BTreeSet<SimTime>> = BTreeMap::new();

    // Step 1: create DAG runs for triggers. The enumerate index is the
    // message's position in the *full* batch — the global promotion step
    // uses it to restore batch arrival order across shards.
    for (batch_idx, msg) in batch.iter().enumerate() {
        if msg.shard_of(n_shards) != shard {
            continue;
        }
        match *msg {
            SchedMsg::Trigger { dag_id, logical_ts, run_type } => {
                let Some(spec) = db.serialized.get(&dag_id) else { continue };
                let paused = db.dags.get(&dag_id).map(|d| d.is_paused).unwrap_or(false);
                // Cron fires are silently dropped while the DAG is
                // paused; manual and backfill triggers bypass the pause
                // gate (Airflow parity: the run is created, parked in
                // `Queued` until unpause for manual runs).
                if run_type == RunType::Scheduled && paused {
                    continue;
                }
                // Backfill dedup (Airflow parity): a logical date that
                // already has a run for this DAG — in the snapshot or
                // created earlier in this very pass — is skipped, so
                // re-POSTing an overlapping range cannot duplicate runs.
                if run_type == RunType::Backfill {
                    let dates = bf_dates
                        .entry(dag_id)
                        .or_insert_with(|| db.logical_dates_of(dag_id));
                    if !dates.insert(logical_ts) {
                        out.stats.backfill_deduped += 1;
                        continue;
                    }
                }
                let st = pass_dags.entry(dag_id).or_insert_with(|| PassDag {
                    base_id: next_run_id(db, dag_id),
                    created: 0,
                    created_fg: 0,
                    snapshot_active_fg: db
                        .dag_runs
                        .of_dag(dag_id)
                        .filter(|(_, r)| {
                            !r.state.is_terminal() && r.run_type != RunType::Backfill
                        })
                        .count() as u64,
                });
                // Airflow `max_active_runs`: cron fires past the gate
                // are skipped (the next fire retries); manual triggers
                // are never dropped — past the gate the run parks in
                // `Queued` and promotes when capacity frees. Backfill
                // runs live under their own budget entirely: they
                // neither consume this gate nor are dropped by it (a
                // dropped backfill trigger would leave a hole in the
                // range).
                let gate_full = run_type != RunType::Backfill
                    && st.snapshot_active_fg + st.created_fg >= spec.max_active_runs as u64;
                if gate_full && run_type == RunType::Scheduled {
                    out.stats.runs_skipped += 1;
                    continue;
                }
                let run_id = st.base_id + st.created;
                // Backfill runs always start `Queued` (promoted below
                // under the backfill budget); a manual run on a paused
                // DAG or past the gate starts `Queued` until it can run;
                // everything else starts `Running`.
                let state = if run_type == RunType::Backfill || paused || gate_full {
                    RunState::Queued
                } else {
                    RunState::Running
                };
                out.txn.push(Write::InsertDagRun(crate::cloud::db::DagRunRow {
                    dag_id,
                    run_id,
                    logical_ts,
                    run_type,
                    state,
                    start: if state == RunState::Running { Some(now) } else { None },
                    end: None,
                }));
                for t in &spec.tasks {
                    out.txn.push(Write::InsertTi(TiRow {
                        dag_id,
                        run_id,
                        task_id: t.id,
                        state: TiState::None,
                        try_number: 0,
                        ready: None,
                        start: None,
                        end: None,
                        host: None,
                        fast_dispatched: false,
                    }));
                }
                st.created += 1;
                if run_type == RunType::Backfill {
                    created_backfill.push((batch_idx, (dag_id, run_id)));
                } else {
                    st.created_fg += 1;
                }
                out.stats.runs_created += 1;
            }
            SchedMsg::DagResumed { .. } => {
                // No bookkeeping needed: the foreground promotion step
                // below runs on every pass and reads the pause flag from
                // the snapshot — this message exists to *cause* a pass
                // right after the unpause commit.
            }
            SchedMsg::RunChanged { dag_id, run_id } => {
                dirty_runs.insert((dag_id, run_id));
            }
            SchedMsg::TaskFinished { dag_id, run_id, .. } => {
                dirty_runs.insert((dag_id, run_id));
            }
        }
    }

    // Runs created in this pass are NOT scheduled here: the DAG-run
    // insertion flows through CDC back to the scheduler (§4.1 "A DAG run
    // event is routed to the scheduler"), and the *next* pass schedules
    // the roots. (MWAA's polling loop picks them up on its next
    // iteration.) Root ready times are therefore the run's start.

    // Runs this pass moves Running -> terminal free capacity for the
    // promotion steps: backfill completions free their *tenant's*
    // backfill budget (accumulated into the cross-shard `backfill_freed`
    // for the global promotion step), foreground completions free their
    // DAG's `max_active_runs` capacity (per-DAG, hence shard-local).
    let mut fg_freed: BTreeMap<DagId, u64> = BTreeMap::new();

    // Steps 2+3 for existing dirty runs, plus run-completion detection.
    // Graphs are built once per DAG per pass (perf: a batch often carries
    // many events of the same DAG).
    let mut graphs: BTreeMap<DagId, DagGraph> = BTreeMap::new();
    for &(dag_id, run_id) in &dirty_runs {
        let Some(run) = db.dag_runs.get(&(dag_id, run_id)) else { continue };
        if run.state.is_terminal() {
            continue;
        }
        let Some(spec) = db.serialized.get(&dag_id) else {
            // The DAG was deleted while this run's events were in flight.
            // Apply-time insert guards keep orphan rows from landing, but
            // a run inserted *before* the delete can still be referenced
            // by in-flight events; fail it so it doesn't count as active
            // forever.
            if run.state == RunState::Running {
                if run.run_type == RunType::Backfill {
                    *backfill_freed.entry(dag_id.tenant()).or_insert(0) += 1;
                } else {
                    *fg_freed.entry(dag_id).or_insert(0) += 1;
                }
            }
            out.txn.push(Write::SetRunState {
                dag_id,
                run_id,
                state: RunState::Failed,
            });
            out.stats.runs_completed += 1;
            continue;
        };
        if run.state == RunState::Queued {
            // A parked run: a manual trigger that landed on a paused DAG
            // or past the `max_active_runs` gate, or an unpromoted
            // backfill run. The promotion steps below are its only way
            // out; nothing to schedule yet.
            continue;
        }
        let graph = graphs.entry(dag_id).or_insert_with(|| DagGraph::of(spec));
        let tis = db.tis_of_run(dag_id, run_id);
        if tis.is_empty() {
            continue;
        }
        // Task ids are dense and `tis` is task-id-ordered (BTreeMap range
        // order), so predecessors are O(1) indexes — no keyed lookups on
        // the hot path.
        debug_assert!(tis.iter().enumerate().all(|(i, t)| t.task_id as usize == i));

        let mut all_terminal = true;
        let mut any_failed = false;
        for ti in &tis {
            if !ti.state.is_terminal() {
                all_terminal = false;
            }
            if matches!(ti.state, TiState::Failed | TiState::UpstreamFailed) {
                any_failed = true;
            }
        }
        if all_terminal {
            if run.run_type == RunType::Backfill {
                *backfill_freed.entry(dag_id.tenant()).or_insert(0) += 1;
            } else {
                *fg_freed.entry(dag_id).or_insert(0) += 1;
            }
            out.txn.push(Write::SetRunState {
                dag_id,
                run_id,
                state: if any_failed { RunState::Failed } else { RunState::Success },
            });
            out.stats.runs_completed += 1;
            continue;
        }

        for ti in &tis {
            match ti.state {
                TiState::None => {
                    // One pass over the predecessors decides everything:
                    // a terminally-failed pred dooms this task (Airflow's
                    // `upstream_failed` propagation); otherwise it becomes
                    // ready once every pred succeeded (ready time = latest
                    // pred end).
                    let preds = &graph.upstream[ti.task_id as usize];
                    let mut ready_at: SimTime = run.start.unwrap_or(now);
                    let mut all_ok = true;
                    let mut doomed = false;
                    for &p in preds {
                        match tis.get(p as usize).map(|r| (r.state, r.end)) {
                            Some((TiState::Success, end)) => {
                                ready_at = ready_at.max(end.unwrap_or(now));
                            }
                            Some((TiState::Failed | TiState::UpstreamFailed, _)) => {
                                doomed = true;
                                break;
                            }
                            _ => all_ok = false,
                        }
                    }
                    if doomed {
                        out.txn.push(Write::SetTiState {
                            key: (dag_id, run_id, ti.task_id),
                            state: TiState::UpstreamFailed,
                        });
                        continue;
                    }
                    if all_ok {
                        let key = (dag_id, run_id, ti.task_id);
                        out.txn.push(Write::SetTiReady { key, ts: ready_at });
                        out.txn.push(Write::SetTiState { key, state: TiState::Scheduled });
                        out.stats.tis_scheduled += 1;
                        if *active < limits.parallelism {
                            out.txn.push(Write::SetTiState { key, state: TiState::Queued });
                            out.stats.tis_queued += 1;
                            *active += 1;
                        }
                    }
                }
                TiState::Scheduled => {
                    // Left over from an earlier pass that hit the
                    // parallelism limit.
                    if *active < limits.parallelism {
                        out.txn.push(Write::SetTiState {
                            key: (dag_id, run_id, ti.task_id),
                            state: TiState::Queued,
                        });
                        out.stats.tis_queued += 1;
                        *active += 1;
                    }
                }
                TiState::UpForRetry => {
                    // Reschedule a failed-but-retryable task.
                    let key = (dag_id, run_id, ti.task_id);
                    out.txn.push(Write::SetTiState { key, state: TiState::Scheduled });
                    out.stats.retries += 1;
                    if *active < limits.parallelism {
                        out.txn.push(Write::SetTiState { key, state: TiState::Queued });
                        out.stats.tis_queued += 1;
                        *active += 1;
                    }
                }
                _ => {
                    // A fast-dispatched successor (docs/FASTPATH.md) shows
                    // up here already `Queued`/`Running`: the worker beat
                    // this pass to it, and the pass reconciles by doing
                    // nothing — which is exactly the fast path's
                    // exactly-once contract.
                    if ti.fast_dispatched {
                        out.stats.fastpath_reconciled_noop += 1;
                    }
                }
            }
        }
    }

    // Foreground promotion: manual runs parked in `Queued` (paused DAG or
    // saturated `max_active_runs` gate) promote once the DAG is unpaused
    // and per-DAG capacity frees. Runs completed by *this* pass free
    // capacity immediately; the promotion's `Running` change routes back
    // through CDC and the next pass launches the roots. `DagResumed` and
    // run-completion events are what bring the pass here.
    let mut fg_capacity: BTreeMap<DagId, u64> = BTreeMap::new();
    for &key in db.queued_foreground() {
        let dag_id = key.0;
        // Foreground promotion is per-DAG policy (pause flag, per-DAG
        // capacity), so each shard's slice promotes only its own DAGs.
        if dag_id.shard_of(n_shards) != shard {
            continue;
        }
        let Some(spec) = db.serialized.get(&dag_id) else { continue };
        if db.dags.get(&dag_id).map(|d| d.is_paused).unwrap_or(false) {
            continue;
        }
        let cap = fg_capacity.entry(dag_id).or_insert_with(|| {
            let running = db
                .dag_runs
                .of_dag(dag_id)
                .filter(|(_, r)| {
                    r.state == RunState::Running && r.run_type != RunType::Backfill
                })
                .count() as u64;
            let freed = fg_freed.get(&dag_id).copied().unwrap_or(0);
            (spec.max_active_runs as u64).saturating_sub(running.saturating_sub(freed))
        });
        if *cap == 0 {
            continue;
        }
        *cap -= 1;
        // `PromoteRun` (not a blind state write): at apply time it only
        // lands while the row is still `Queued`, so a promotion racing a
        // concurrent mark-terminal cannot revive the cancelled run.
        out.txn.push(Write::PromoteRun { dag_id, run_id: key.1 });
        out.stats.runs_promoted += 1;
    }
    // Backfill promotion happens in [`scheduling_pass_sharded`]'s global
    // step, after every shard's slice ran: the promotion FIFO and the
    // per-tenant budgets span shards.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::db::{DagRow, MetaDb};
    use crate::sim::time::SECOND;
    use crate::workloads::synthetic::{chain_dag, parallel_dag};

    fn db_with(spec: &crate::dag::spec::DagSpec) -> MetaDb {
        let mut db = MetaDb::new();
        let mut txn = Txn::new();
        txn.push(Write::UpsertDag(DagRow {
            dag_id: spec.dag_id,
            fileloc: format!("dags/{}.json", spec.dag_id),
            period: spec.period,
            is_paused: false,
        }));
        txn.push(Write::PutSerializedDag(spec.clone()));
        db.apply(txn, 0);
        db
    }

    fn trigger_msg(dag_id: &str, logical_ts: u64, run_type: RunType) -> SchedMsg {
        SchedMsg::Trigger { dag_id: dag_id.into(), logical_ts, run_type }
    }

    fn periodic(dag_id: &str) -> Vec<SchedMsg> {
        vec![trigger_msg(dag_id, 0, RunType::Scheduled)]
    }

    /// Advance a run by one RunChanged pass (what the CDC DAG-run event
    /// triggers in sAirflow, or the next polling iteration in MWAA).
    fn advance(db: &mut MetaDb, dag_id: &str, run_id: u64, now: u64) -> PassStats {
        let msg = vec![SchedMsg::RunChanged { dag_id: dag_id.into(), run_id }];
        let out = scheduling_pass(db, now, &msg, &SchedLimits::default());
        let stats = out.stats.clone();
        db.apply(out.txn, now);
        stats
    }

    #[test]
    fn periodic_creates_run_then_next_pass_queues_roots() {
        let spec = chain_dag("c", 3, 10.0, 5.0);
        let mut db = db_with(&spec);
        // Pass 1 (periodic event): creates the run + TIs, queues nothing —
        // the DAG-run event flows back through CDC (§4.1).
        let out = scheduling_pass(&db, SECOND, &periodic("c"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        assert_eq!(out.stats.tis_scheduled, 0);
        db.apply(out.txn, SECOND);
        assert_eq!(db.dag_runs.len(), 1);
        assert_eq!(db.task_instances.len(), 3);
        // Pass 2 (DAG-run event): schedules + queues the chain head.
        let stats = advance(&mut db, "c", 1, 2 * SECOND);
        assert_eq!(stats.tis_scheduled, 1);
        assert_eq!(stats.tis_queued, 1);
        let root = &db.task_instances[&("c".into(), 1, 0)];
        assert_eq!(root.state, TiState::Queued);
        // Root ready time = the run's start (creation commit), not pass 2.
        assert_eq!(root.ready, Some(SECOND));
    }

    #[test]
    fn parallel_queues_all_after_root_success() {
        let spec = parallel_dag("p", 5, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("p"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "p", 1, 0); // queue the root
        // Simulate root running + success.
        let key: crate::cloud::db::TiKey = ("p".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 2 * SECOND);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "p".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 3 * SECOND, &msg, &SchedLimits::default());
        assert_eq!(out.stats.tis_scheduled, 5);
        assert_eq!(out.stats.tis_queued, 5);
        db.apply(out.txn, 3 * SECOND);
        // Successor ready time = predecessor end (2 s), not pass time (3 s).
        let ti = &db.task_instances[&("p".into(), 1, 1)];
        assert_eq!(ti.ready, Some(2 * SECOND));
        assert_eq!(ti.state, TiState::Queued);
    }

    #[test]
    fn parallelism_limit_enforced() {
        let spec = parallel_dag("p", 50, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("p"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "p", 1, 0); // queue the root
        // Root success.
        let key: crate::cloud::db::TiKey = ("p".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 2);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "p".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let limits = SchedLimits { parallelism: 10, ..SchedLimits::default() };
        let out = scheduling_pass(&db, 3, &msg, &limits);
        assert_eq!(out.stats.tis_scheduled, 50);
        assert_eq!(out.stats.tis_queued, 10, "only 10 slots");
        db.apply(out.txn, 3);
        // While saturated, later passes queue nothing more.
        let out2 = scheduling_pass(
            &db,
            4,
            &[SchedMsg::RunChanged { dag_id: "p".into(), run_id: 1 }],
            &limits,
        );
        assert_eq!(out2.stats.tis_queued, 0, "still saturated");
    }

    #[test]
    fn run_completion_detected() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "c", 1, 0); // queue the root
        let key: crate::cloud::db::TiKey = ("c".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 11 * SECOND);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 12 * SECOND, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_completed, 1);
        db.apply(out.txn, 12 * SECOND);
        let run = &db.dag_runs[&("c".into(), 1)];
        assert_eq!(run.state, RunState::Success);
        assert_eq!(run.end, Some(12 * SECOND));
    }

    #[test]
    fn retry_rescheduled_then_failed_run() {
        let mut spec = chain_dag("c", 1, 10.0, 5.0);
        spec.tasks[0].retries = 1;
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "c", 1, 0); // queue the root
        let key: crate::cloud::db::TiKey = ("c".into(), 1, 0u32);
        // First try fails -> UpForRetry.
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::UpForRetry });
        db.apply(t, 2);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::UpForRetry,
        }];
        let out = scheduling_pass(&db, 3, &msg, &SchedLimits::default());
        assert_eq!(out.stats.retries, 1);
        db.apply(out.txn, 3);
        assert_eq!(db.task_instances[&key].state, TiState::Queued);
        // Second try fails terminally.
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Failed });
        db.apply(t, 5);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Failed,
        }];
        let out = scheduling_pass(&db, 6, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_completed, 1);
        db.apply(out.txn, 6);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Failed);
    }

    #[test]
    fn run_of_deleted_dag_is_failed_not_stuck() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        // The DAG disappears (DELETE raced the run-creation txn) while the
        // run's change event is still in flight.
        db.serialized.remove("c");
        db.dags.remove("c");
        let stats = advance(&mut db, "c", 1, 2);
        assert_eq!(stats.runs_completed, 1);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Failed);
        // Terminal now: later passes leave it alone.
        let stats = advance(&mut db, "c", 1, 3);
        assert_eq!(stats.runs_completed, 0);
    }

    #[test]
    fn unknown_dag_ignored() {
        let db = MetaDb::new();
        let out = scheduling_pass(&db, 0, &periodic("ghost"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
        assert!(out.txn.is_empty());
    }

    #[test]
    fn paused_dag_not_run() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        db.dags.get_mut("c").unwrap().is_paused = true;
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
    }

    #[test]
    fn max_active_runs_gates_triggers() {
        let spec = chain_dag("slow", 1, 10.0, 5.0).max_active_runs(1);
        let mut db = db_with(&spec);
        // First trigger creates a run.
        let out = scheduling_pass(&db, 0, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        db.apply(out.txn, 0);
        // Second trigger while run 1 is active: skipped.
        let out = scheduling_pass(&db, 1, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
        assert_eq!(out.stats.runs_skipped, 1);
        // Complete run 1, then the next trigger goes through.
        advance(&mut db, "slow", 1, 2);
        let key: crate::cloud::db::TiKey = ("slow".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 3);
        advance(&mut db, "slow", 1, 4); // marks run terminal
        let out = scheduling_pass(&db, 5, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
    }

    #[test]
    fn manual_trigger_bypasses_pause_gate() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        db.dags.get_mut("c").unwrap().is_paused = true;
        // Cron fire: dropped. Manual trigger: creates a *queued* run
        // (Airflow parity — the run exists instead of a 409).
        let batch = vec![
            trigger_msg("c", 0, RunType::Scheduled),
            trigger_msg("c", 1, RunType::Manual),
        ];
        let out = scheduling_pass(&db, SECOND, &batch, &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        db.apply(out.txn, SECOND);
        let run = &db.dag_runs[&("c".into(), 1)];
        assert_eq!(run.run_type, RunType::Manual);
        assert_eq!(run.state, RunState::Queued);
        assert_eq!(run.start, None, "parked run has not started");
        // While paused, RunChanged passes leave it parked.
        let stats = advance(&mut db, "c", 1, 2 * SECOND);
        assert_eq!(stats.runs_promoted, 0);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Queued);
        // Unpause: the DagResumed event promotes it to Running.
        db.dags.get_mut("c").unwrap().is_paused = false;
        let out = scheduling_pass(
            &db,
            3 * SECOND,
            &[SchedMsg::DagResumed { dag_id: "c".into() }],
            &SchedLimits::default(),
        );
        assert_eq!(out.stats.runs_promoted, 1);
        db.apply(out.txn, 3 * SECOND);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Running);
        // The next RunChanged pass queues the root.
        let stats = advance(&mut db, "c", 1, 4 * SECOND);
        assert_eq!(stats.tis_queued, 1);
    }

    #[test]
    fn manual_trigger_past_gate_parks_and_promotes_on_completion() {
        // A manual trigger is never dropped: past the `max_active_runs`
        // gate the run parks in `Queued` and promotes when capacity
        // frees (cron fires past the gate are still skipped).
        let spec = chain_dag("g", 1, 10.0, 5.0).max_active_runs(1);
        let mut db = db_with(&spec);
        let limits = SchedLimits::default();
        let out = scheduling_pass(&db, 0, &[trigger_msg("g", 0, RunType::Manual)], &limits);
        assert_eq!(out.stats.runs_created, 1);
        db.apply(out.txn, 0);
        assert_eq!(db.dag_runs[&("g".into(), 1)].state, RunState::Running);
        // Second manual trigger while run 1 holds the only slot.
        let out = scheduling_pass(&db, 1, &[trigger_msg("g", 1, RunType::Manual)], &limits);
        assert_eq!(out.stats.runs_created, 1, "parked, not dropped");
        assert_eq!(out.stats.runs_skipped, 0);
        db.apply(out.txn, 1);
        assert_eq!(db.dag_runs[&("g".into(), 2)].state, RunState::Queued);
        // While the slot is held, passes keep it parked.
        let stats = advance(&mut db, "g", 2, 2);
        assert_eq!(stats.runs_promoted, 0, "gate still full");
        // Complete run 1; the completion pass promotes run 2.
        advance(&mut db, "g", 1, 3); // queue run 1's root
        let key: crate::cloud::db::TiKey = ("g".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 4);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "g".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 5, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_completed, 1);
        assert_eq!(out.stats.runs_promoted, 1, "freed slot promotes the parked run");
        db.apply(out.txn, 5);
        assert_eq!(db.dag_runs[&("g".into(), 2)].state, RunState::Running);
    }

    #[test]
    fn manual_trigger_on_unpaused_dag_runs_immediately() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let msg = vec![trigger_msg("c", 0, RunType::Manual)];
        let out = scheduling_pass(&db, SECOND, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        db.apply(out.txn, SECOND);
        let run = &db.dag_runs[&("c".into(), 1)];
        assert_eq!(run.run_type, RunType::Manual);
        assert_eq!(run.state, RunState::Running);
        assert_eq!(run.start, Some(SECOND));
    }

    #[test]
    fn backfill_runs_promoted_under_budget() {
        let spec = chain_dag("b", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let limits = SchedLimits { max_active_backfill_runs: 2, ..SchedLimits::default() };
        let batch: Vec<SchedMsg> =
            (0..5).map(|i| trigger_msg("b", i * SECOND, RunType::Backfill)).collect();
        let out = scheduling_pass(&db, SECOND, &batch, &limits);
        assert_eq!(out.stats.runs_created, 5, "the whole range materializes");
        assert_eq!(out.stats.runs_promoted, 2, "budget promotes two");
        db.apply(out.txn, SECOND);
        assert_eq!(db.active_backfill_count(), 2);
        assert_eq!(db.queued_backfill_count(), 3);
        // A later pass with no budget change promotes nothing more
        // (explicit pass: `advance` would use the default limits).
        let msg = vec![SchedMsg::RunChanged { dag_id: "b".into(), run_id: 1 }];
        let out = scheduling_pass(&db, 2 * SECOND, &msg, &limits);
        assert_eq!(out.stats.runs_promoted, 0, "budget still saturated");
        db.apply(out.txn, 2 * SECOND); // queues run 1's root
        // Complete run 1's task; the pass that detects the completion
        // frees budget and promotes the next queued run in the same txn.
        let key: crate::cloud::db::TiKey = ("b".into(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key, state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 3 * SECOND);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "b".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 4 * SECOND, &msg, &limits);
        assert_eq!(out.stats.runs_completed, 1);
        assert_eq!(out.stats.runs_promoted, 1, "freed slot promotes run 3");
        db.apply(out.txn, 4 * SECOND);
        assert_eq!(db.active_backfill_count(), 2);
        assert_eq!(db.queued_backfill_count(), 2);
    }

    #[test]
    fn backfill_does_not_consume_max_active_runs() {
        let spec = chain_dag("m", 1, 10.0, 5.0).max_active_runs(1);
        let mut db = db_with(&spec);
        let limits = SchedLimits::default();
        let batch: Vec<SchedMsg> =
            (0..2).map(|i| trigger_msg("m", i, RunType::Backfill)).collect();
        let out = scheduling_pass(&db, 0, &batch, &limits);
        assert_eq!(out.stats.runs_created, 2);
        db.apply(out.txn, 0);
        // A cron fire still creates its run: backfill runs are outside
        // the `max_active_runs` gate.
        let out = scheduling_pass(&db, 1, &periodic("m"), &limits);
        assert_eq!(out.stats.runs_created, 1);
        assert_eq!(out.stats.runs_skipped, 0);
        db.apply(out.txn, 1);
        assert_eq!(db.dag_runs.len(), 3);
        // But a second cron fire is gated by the now-active cron run.
        let out = scheduling_pass(&db, 2, &periodic("m"), &limits);
        assert_eq!(out.stats.runs_created, 0);
        assert_eq!(out.stats.runs_skipped, 1);
    }

    #[test]
    fn mixed_batch_keeps_id_and_gate_accounting_consistent() {
        // Regression for the same-pass bookkeeping audit: a batch mixing
        // run creation with `RunChanged` for the same DAG must neither
        // double-count the `max_active_runs` gate nor collide run ids.
        let spec = chain_dag("x", 1, 10.0, 5.0).max_active_runs(3);
        let mut db = db_with(&spec);
        // Run 1 exists and is active.
        let out = scheduling_pass(&db, 0, &periodic("x"), &SchedLimits::default());
        db.apply(out.txn, 0);
        let batch = vec![
            trigger_msg("x", 1, RunType::Scheduled),
            SchedMsg::RunChanged { dag_id: "x".into(), run_id: 1 },
            trigger_msg("x", 2, RunType::Manual),
        ];
        let out = scheduling_pass(&db, SECOND, &batch, &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 2, "one active + two new fits gate 3");
        assert_eq!(out.stats.runs_skipped, 0);
        db.apply(out.txn, SECOND);
        assert_eq!(db.dag_runs.len(), 3, "distinct run ids, no overwrite");
        assert!(db.dag_runs.contains_key(&("x".into(), 2)));
        assert!(db.dag_runs.contains_key(&("x".into(), 3)));
        // The gate is now full: one more trigger is skipped.
        let out = scheduling_pass(&db, 2 * SECOND, &periodic("x"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
        assert_eq!(out.stats.runs_skipped, 1);
    }

    #[test]
    fn backfill_dedup_skips_existing_logical_dates() {
        let spec = chain_dag("b", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let limits = SchedLimits::default();
        // First range: dates 0, 60, 120.
        let batch: Vec<SchedMsg> =
            [0u64, 60, 120].iter().map(|&t| trigger_msg("b", t, RunType::Backfill)).collect();
        let out = scheduling_pass(&db, 0, &batch, &limits);
        assert_eq!(out.stats.runs_created, 3);
        assert_eq!(out.stats.backfill_deduped, 0);
        db.apply(out.txn, 0);
        // Overlapping re-POST: 60, 120, 180 — only 180 is new.
        let batch: Vec<SchedMsg> =
            [60u64, 120, 180].iter().map(|&t| trigger_msg("b", t, RunType::Backfill)).collect();
        let out = scheduling_pass(&db, 1, &batch, &limits);
        assert_eq!(out.stats.runs_created, 1, "only the new date materializes");
        assert_eq!(out.stats.backfill_deduped, 2);
        db.apply(out.txn, 1);
        assert_eq!(db.dag_runs.len(), 4);
        // Same-pass duplicates (two identical POSTs batched together)
        // dedup too.
        let batch = vec![
            trigger_msg("b", 240, RunType::Backfill),
            trigger_msg("b", 240, RunType::Backfill),
        ];
        let out = scheduling_pass(&db, 2, &batch, &limits);
        assert_eq!(out.stats.runs_created, 1);
        assert_eq!(out.stats.backfill_deduped, 1);
        // Manual triggers are never deduped (same logical date is fine).
        db.apply(out.txn, 2);
        let batch = vec![
            trigger_msg("b", 240, RunType::Manual),
            trigger_msg("b", 240, RunType::Manual),
        ];
        let out = scheduling_pass(&db, 3, &batch, &limits);
        assert_eq!(out.stats.runs_created, 2);
        assert_eq!(out.stats.backfill_deduped, 0);
    }

    #[test]
    fn interleaved_backfills_of_two_dags_drain_fifo_by_arrival() {
        // Regression for the cross-DAG fairness item: "zzz" backfills
        // strictly before "aaa"; with a budget of 1 the promotions must
        // follow arrival order, not lexicographic (dag_id, run_id) order.
        let zzz = chain_dag("zzz", 1, 10.0, 5.0);
        let aaa = chain_dag("aaa", 1, 10.0, 5.0);
        let mut db = db_with(&zzz);
        let mut txn = Txn::new();
        txn.push(Write::UpsertDag(DagRow {
            dag_id: aaa.dag_id.as_str().into(),
            fileloc: "dags/aaa.json".into(),
            period: aaa.period,
            is_paused: false,
        }));
        txn.push(Write::PutSerializedDag(aaa.clone()));
        db.apply(txn, 0);
        let limits = SchedLimits { max_active_backfill_runs: 1, ..SchedLimits::default() };
        // zzz's range arrives first, aaa's second (interleaved in one
        // batch, as back-to-back POSTs would land on the FIFO feed).
        let batch = vec![
            trigger_msg("zzz", 0, RunType::Backfill),
            trigger_msg("zzz", 60, RunType::Backfill),
            trigger_msg("aaa", 0, RunType::Backfill),
            trigger_msg("aaa", 60, RunType::Backfill),
        ];
        let out = scheduling_pass(&db, 0, &batch, &limits);
        assert_eq!(out.stats.runs_created, 4);
        assert_eq!(out.stats.runs_promoted, 1, "budget 1: one promotion");
        db.apply(out.txn, 0);
        // The promoted run is zzz/1 — first arrival, despite "aaa" < "zzz".
        assert_eq!(db.dag_runs[&("zzz".into(), 1)].state, RunState::Running);
        assert_eq!(db.dag_runs[&("aaa".into(), 1)].state, RunState::Queued);
        // Drain: complete the running run, observe the next promotion.
        let mut promoted_order: Vec<RunKey> = vec![("zzz".into(), 1)];
        for step in 0..3 {
            let (key, _) = db
                .dag_runs
                .iter()
                .find(|(_, r)| r.state == RunState::Running)
                .map(|(k, r)| (*k, r.run_id))
                .expect("one running backfill");
            let mut t = Txn::new();
            t.push(Write::SetRunState {
                dag_id: key.0,
                run_id: key.1,
                state: RunState::Success,
            });
            db.apply(t, 10 + step);
            let msg = vec![SchedMsg::DagResumed { dag_id: key.0 }];
            let out = scheduling_pass(&db, 11 + step, &msg, &limits);
            assert_eq!(out.stats.runs_promoted, 1, "freed slot promotes next arrival");
            db.apply(out.txn, 11 + step);
            let next = db
                .dag_runs
                .iter()
                .find(|(_, r)| r.state == RunState::Running)
                .map(|(k, _)| *k)
                .expect("next backfill promoted");
            promoted_order.push(next);
        }
        assert_eq!(
            promoted_order,
            vec![
                ("zzz".into(), 1),
                ("zzz".into(), 2),
                ("aaa".into(), 1),
                ("aaa".into(), 2),
            ],
            "FIFO by arrival across DAGs"
        );
    }

    #[test]
    fn backfill_budgets_are_per_tenant() {
        use crate::cloud::db::TenantRow;
        use crate::dag::state::scoped_dag_id;
        // Tenant "acme" overrides its budget to 1; "globex" uses the
        // deployment default (2). Saturating acme must not block globex.
        let acme_dag = scoped_dag_id("acme", "etl");
        let globex_dag = scoped_dag_id("globex", "etl");
        let mut spec_a = chain_dag(&acme_dag, 1, 10.0, 5.0);
        spec_a.period = None;
        let mut db = db_with(&spec_a);
        let mut spec_g = chain_dag(&globex_dag, 1, 10.0, 5.0);
        spec_g.period = None;
        let mut txn = Txn::new();
        txn.push(Write::UpsertDag(DagRow {
            dag_id: globex_dag.as_str().into(),
            fileloc: String::new(),
            period: None,
            is_paused: false,
        }));
        txn.push(Write::PutSerializedDag(spec_g));
        txn.push(Write::UpsertTenant {
            row: TenantRow {
                tenant_id: "acme".into(),
                token: None,
                rate: None,
                max_active_backfill_runs: Some(1),
            },
            expected_token: None,
        });
        db.apply(txn, 0);
        let limits = SchedLimits { max_active_backfill_runs: 2, ..SchedLimits::default() };
        // Acme's big range arrives before globex's — with a shared budget
        // acme would starve globex; per-tenant budgets promote 1 + 2.
        let mut batch: Vec<SchedMsg> =
            (0..4).map(|i| trigger_msg(&acme_dag, i * 60, RunType::Backfill)).collect();
        batch.extend((0..3).map(|i| trigger_msg(&globex_dag, i * 60, RunType::Backfill)));
        let out = scheduling_pass(&db, 0, &batch, &limits);
        assert_eq!(out.stats.runs_created, 7);
        assert_eq!(out.stats.runs_promoted, 3, "1 acme (override) + 2 globex (default)");
        db.apply(out.txn, 0);
        assert_eq!(db.active_backfill_count_of("acme"), 1);
        assert_eq!(db.active_backfill_count_of("globex"), 2);
        assert_eq!(db.queued_backfill_count(), 4);
    }

    #[test]
    fn two_periodics_same_pass_get_distinct_runs() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let batch = vec![
            trigger_msg("c", 0, RunType::Scheduled),
            trigger_msg("c", 1, RunType::Scheduled),
        ];
        let out = scheduling_pass(&db, 0, &batch, &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 2);
        db.apply(out.txn, 0);
        assert_eq!(db.dag_runs.len(), 2);
        assert!(db.dag_runs.contains_key(&("c".into(), 1)));
        assert!(db.dag_runs.contains_key(&("c".into(), 2)));
    }
}
