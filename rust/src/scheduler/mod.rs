//! The event-triggered scheduler (§4.3).
//!
//! Airflow runs the scheduler as an always-on thread; sAirflow executes a
//! *single pass* of the same algorithm per FaaS invocation, triggered by
//! events (a completed task, a new DAG run, a periodic cron fire). For
//! consistency, passes are fed from a single-shard FIFO queue — the
//! serverless surrogate of Airflow's scheduler critical section.
//!
//! The pass itself is a pure function from a metadata-database snapshot
//! and an event batch to a transaction ([`scheduling_pass`]) — exactly the
//! paper's three steps:
//!
//! 1. for each DAG ready to execute: create a DAG run;
//! 2. for each task in each DAG run with all predecessors completed:
//!    create a *scheduled* task instance;
//! 3. for each scheduled task instance, label it *queued*.
//!
//! Being pure, the pass is directly property-testable (see
//! `rust/tests/prop_scheduler.rs`). The MWAA baseline reuses this exact
//! pass inside its polling loop — same Airflow semantics, different
//! triggering model.

use crate::cloud::db::{MetaDb, TiRow, Txn, Write};
use crate::dag::graph::DagGraph;
use crate::dag::state::{RunState, TiState};
use crate::sim::time::SimTime;
use std::collections::{BTreeSet, HashMap};

/// Messages feeding the scheduler (the FIFO queue payload).
#[derive(Debug, Clone, PartialEq)]
pub enum SchedMsg {
    /// A periodic cron fire: a single launch of a scheduled workflow.
    Periodic { dag_id: String, logical_ts: SimTime },
    /// A DAG run row changed (e.g. the run was created).
    RunChanged { dag_id: String, run_id: u64 },
    /// A task instance reached a terminal-ish state
    /// (success / failed / up-for-retry).
    TaskFinished { dag_id: String, run_id: u64, task_id: u32, state: TiState },
}

/// Scheduler limits, matching the paper's deployment (§5): both systems
/// support at most 125 concurrent task instances.
#[derive(Debug, Clone)]
pub struct SchedLimits {
    /// Maximum queued+running task instances across all DAGs.
    pub parallelism: usize,
}

impl Default for SchedLimits {
    fn default() -> SchedLimits {
        SchedLimits { parallelism: 125 }
    }
}

/// Statistics of one pass (for reporting/tests).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct PassStats {
    pub runs_created: usize,
    /// Periodic triggers skipped by the `max_active_runs` gate.
    pub runs_skipped: usize,
    pub tis_scheduled: usize,
    pub tis_queued: usize,
    pub runs_completed: usize,
    pub retries: usize,
}

/// Output of a scheduling pass: the transaction to commit plus statistics.
#[derive(Debug, Default)]
pub struct PassOutput {
    pub txn: Txn,
    pub stats: PassStats,
}

/// Next run id for a DAG (1-based, dense).
fn next_run_id(db: &MetaDb, dag_id: &str) -> u64 {
    db.dag_runs
        .range((dag_id.to_string(), 0)..=(dag_id.to_string(), u64::MAX))
        .map(|((_, r), _)| *r)
        .max()
        .unwrap_or(0)
        + 1
}

/// Execute one scheduling pass over a database snapshot.
///
/// `now` is the pass time (used for run start and ready computation when a
/// predecessor end time is unknown). The returned transaction must be
/// committed by the caller; because passes are serialized by the FIFO
/// feed, the snapshot cannot race with another pass.
pub fn scheduling_pass(
    db: &MetaDb,
    now: SimTime,
    batch: &[SchedMsg],
    limits: &SchedLimits,
) -> PassOutput {
    let mut out = PassOutput::default();
    // Runs that this pass must (re)examine.
    let mut dirty_runs: BTreeSet<(String, u64)> = BTreeSet::new();

    // Step 1: create DAG runs for periodic triggers.
    let mut created_runs: Vec<(String, u64)> = Vec::new();
    for msg in batch {
        match msg {
            SchedMsg::Periodic { dag_id, logical_ts } => {
                let Some(spec) = db.serialized.get(dag_id) else { continue };
                if db.dags.get(dag_id).map(|d| d.is_paused).unwrap_or(false) {
                    continue;
                }
                // Account for runs created earlier in this same pass.
                let already =
                    created_runs.iter().filter(|(d, _)| d == dag_id).count() as u64;
                // Airflow `max_active_runs`: skip the trigger while too
                // many runs of this DAG are still active.
                let active_runs = db
                    .dag_runs
                    .range((dag_id.clone(), 0)..=(dag_id.clone(), u64::MAX))
                    .filter(|(_, r)| !r.state.is_terminal())
                    .count() as u64
                    + already;
                if active_runs >= spec.max_active_runs as u64 {
                    out.stats.runs_skipped += 1;
                    continue;
                }
                let run_id = next_run_id(db, dag_id) + already;
                out.txn.push(Write::InsertDagRun(crate::cloud::db::DagRunRow {
                    dag_id: dag_id.clone(),
                    run_id,
                    logical_ts: *logical_ts,
                    state: RunState::Running,
                    start: Some(now),
                    end: None,
                }));
                for t in &spec.tasks {
                    out.txn.push(Write::InsertTi(TiRow {
                        dag_id: dag_id.clone(),
                        run_id,
                        task_id: t.id,
                        state: TiState::None,
                        try_number: 0,
                        ready: None,
                        start: None,
                        end: None,
                        host: None,
                    }));
                }
                created_runs.push((dag_id.clone(), run_id));
                out.stats.runs_created += 1;
            }
            SchedMsg::RunChanged { dag_id, run_id } => {
                dirty_runs.insert((dag_id.clone(), *run_id));
            }
            SchedMsg::TaskFinished { dag_id, run_id, .. } => {
                dirty_runs.insert((dag_id.clone(), *run_id));
            }
        }
    }

    // Current global active count for the parallelism limit; queue decisions
    // in this pass immediately consume budget.
    let mut active = db.active_ti_count();

    // Runs created in this pass are NOT scheduled here: the DAG-run
    // insertion flows through CDC back to the scheduler (§4.1 "A DAG run
    // event is routed to the scheduler"), and the *next* pass schedules
    // the roots. (MWAA's polling loop picks them up on its next
    // iteration.) Root ready times are therefore the run's start.
    let _ = &created_runs;

    // Steps 2+3 for existing dirty runs, plus run-completion detection.
    // Graphs are built once per DAG per pass (perf: a batch often carries
    // many events of the same DAG).
    let mut graphs: HashMap<&str, DagGraph> = HashMap::new();
    for (dag_id, run_id) in &dirty_runs {
        let Some(run) = db.dag_runs.get(&(dag_id.clone(), *run_id)) else { continue };
        if run.state.is_terminal() {
            continue;
        }
        let Some(spec) = db.serialized.get(dag_id) else {
            // The DAG was deleted while this run's events were in flight
            // (a scheduling txn built from a pre-delete snapshot can
            // re-insert rows after DeleteDag applies). Fail the orphan so
            // it doesn't count as active forever.
            out.txn.push(Write::SetRunState {
                dag_id: dag_id.clone(),
                run_id: *run_id,
                state: RunState::Failed,
            });
            out.stats.runs_completed += 1;
            continue;
        };
        let graph = graphs
            .entry(spec.dag_id.as_str())
            .or_insert_with(|| DagGraph::of(spec));
        let tis = db.tis_of_run(dag_id, *run_id);
        if tis.is_empty() {
            continue;
        }
        // Task ids are dense and `tis` is task-id-ordered (BTreeMap range
        // order), so predecessors are O(1) indexes — no keyed lookups on
        // the hot path.
        debug_assert!(tis.iter().enumerate().all(|(i, t)| t.task_id as usize == i));

        let mut all_terminal = true;
        let mut any_failed = false;
        for ti in &tis {
            if !ti.state.is_terminal() {
                all_terminal = false;
            }
            if matches!(ti.state, TiState::Failed | TiState::UpstreamFailed) {
                any_failed = true;
            }
        }
        if all_terminal {
            out.txn.push(Write::SetRunState {
                dag_id: dag_id.clone(),
                run_id: *run_id,
                state: if any_failed { RunState::Failed } else { RunState::Success },
            });
            out.stats.runs_completed += 1;
            continue;
        }

        for ti in &tis {
            match ti.state {
                TiState::None => {
                    // One pass over the predecessors decides everything:
                    // a terminally-failed pred dooms this task (Airflow's
                    // `upstream_failed` propagation); otherwise it becomes
                    // ready once every pred succeeded (ready time = latest
                    // pred end).
                    let preds = &graph.upstream[ti.task_id as usize];
                    let mut ready_at: SimTime = run.start.unwrap_or(now);
                    let mut all_ok = true;
                    let mut doomed = false;
                    for &p in preds {
                        match tis.get(p as usize).map(|r| (r.state, r.end)) {
                            Some((TiState::Success, end)) => {
                                ready_at = ready_at.max(end.unwrap_or(now));
                            }
                            Some((TiState::Failed | TiState::UpstreamFailed, _)) => {
                                doomed = true;
                                break;
                            }
                            _ => all_ok = false,
                        }
                    }
                    if doomed {
                        out.txn.push(Write::SetTiState {
                            key: (dag_id.clone(), *run_id, ti.task_id),
                            state: TiState::UpstreamFailed,
                        });
                        continue;
                    }
                    if all_ok {
                        let key = (dag_id.clone(), *run_id, ti.task_id);
                        out.txn.push(Write::SetTiReady { key: key.clone(), ts: ready_at });
                        out.txn.push(Write::SetTiState { key: key.clone(), state: TiState::Scheduled });
                        out.stats.tis_scheduled += 1;
                        if active < limits.parallelism {
                            out.txn.push(Write::SetTiState {
                                key,
                                state: TiState::Queued,
                            });
                            out.stats.tis_queued += 1;
                            active += 1;
                        }
                    }
                }
                TiState::Scheduled => {
                    // Left over from an earlier pass that hit the
                    // parallelism limit.
                    if active < limits.parallelism {
                        out.txn.push(Write::SetTiState {
                            key: (dag_id.clone(), *run_id, ti.task_id),
                            state: TiState::Queued,
                        });
                        out.stats.tis_queued += 1;
                        active += 1;
                    }
                }
                TiState::UpForRetry => {
                    // Reschedule a failed-but-retryable task.
                    let key = (dag_id.clone(), *run_id, ti.task_id);
                    out.txn.push(Write::SetTiState { key: key.clone(), state: TiState::Scheduled });
                    out.stats.retries += 1;
                    if active < limits.parallelism {
                        out.txn.push(Write::SetTiState { key, state: TiState::Queued });
                        out.stats.tis_queued += 1;
                        active += 1;
                    }
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::db::{DagRow, MetaDb};
    use crate::sim::time::SECOND;
    use crate::workloads::synthetic::{chain_dag, parallel_dag};

    fn db_with(spec: &crate::dag::spec::DagSpec) -> MetaDb {
        let mut db = MetaDb::new();
        let mut txn = Txn::new();
        txn.push(Write::UpsertDag(DagRow {
            dag_id: spec.dag_id.clone(),
            fileloc: format!("dags/{}.json", spec.dag_id),
            period: spec.period,
            is_paused: false,
        }));
        txn.push(Write::PutSerializedDag(spec.clone()));
        db.apply(txn, 0);
        db
    }

    fn periodic(dag_id: &str) -> Vec<SchedMsg> {
        vec![SchedMsg::Periodic { dag_id: dag_id.into(), logical_ts: 0 }]
    }

    /// Advance a run by one RunChanged pass (what the CDC DAG-run event
    /// triggers in sAirflow, or the next polling iteration in MWAA).
    fn advance(db: &mut MetaDb, dag_id: &str, run_id: u64, now: u64) -> PassStats {
        let msg = vec![SchedMsg::RunChanged { dag_id: dag_id.into(), run_id }];
        let out = scheduling_pass(db, now, &msg, &SchedLimits::default());
        let stats = out.stats.clone();
        db.apply(out.txn, now);
        stats
    }

    #[test]
    fn periodic_creates_run_then_next_pass_queues_roots() {
        let spec = chain_dag("c", 3, 10.0, 5.0);
        let mut db = db_with(&spec);
        // Pass 1 (periodic event): creates the run + TIs, queues nothing —
        // the DAG-run event flows back through CDC (§4.1).
        let out = scheduling_pass(&db, SECOND, &periodic("c"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        assert_eq!(out.stats.tis_scheduled, 0);
        db.apply(out.txn, SECOND);
        assert_eq!(db.dag_runs.len(), 1);
        assert_eq!(db.task_instances.len(), 3);
        // Pass 2 (DAG-run event): schedules + queues the chain head.
        let stats = advance(&mut db, "c", 1, 2 * SECOND);
        assert_eq!(stats.tis_scheduled, 1);
        assert_eq!(stats.tis_queued, 1);
        let root = &db.task_instances[&("c".into(), 1, 0)];
        assert_eq!(root.state, TiState::Queued);
        // Root ready time = the run's start (creation commit), not pass 2.
        assert_eq!(root.ready, Some(SECOND));
    }

    #[test]
    fn parallel_queues_all_after_root_success() {
        let spec = parallel_dag("p", 5, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("p"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "p", 1, 0); // queue the root
        // Simulate root running + success.
        let key = ("p".to_string(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Success });
        db.apply(t, 2 * SECOND);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "p".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 3 * SECOND, &msg, &SchedLimits::default());
        assert_eq!(out.stats.tis_scheduled, 5);
        assert_eq!(out.stats.tis_queued, 5);
        db.apply(out.txn, 3 * SECOND);
        // Successor ready time = predecessor end (2 s), not pass time (3 s).
        let ti = &db.task_instances[&("p".into(), 1, 1)];
        assert_eq!(ti.ready, Some(2 * SECOND));
        assert_eq!(ti.state, TiState::Queued);
    }

    #[test]
    fn parallelism_limit_enforced() {
        let spec = parallel_dag("p", 50, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("p"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "p", 1, 0); // queue the root
        // Root success.
        let key = ("p".to_string(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 2);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "p".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let limits = SchedLimits { parallelism: 10 };
        let out = scheduling_pass(&db, 3, &msg, &limits);
        assert_eq!(out.stats.tis_scheduled, 50);
        assert_eq!(out.stats.tis_queued, 10, "only 10 slots");
        db.apply(out.txn, 3);
        // While saturated, later passes queue nothing more.
        let out2 = scheduling_pass(
            &db,
            4,
            &[SchedMsg::RunChanged { dag_id: "p".into(), run_id: 1 }],
            &limits,
        );
        assert_eq!(out2.stats.tis_queued, 0, "still saturated");
    }

    #[test]
    fn run_completion_detected() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "c", 1, 0); // queue the root
        let key = ("c".to_string(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 11 * SECOND);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Success,
        }];
        let out = scheduling_pass(&db, 12 * SECOND, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_completed, 1);
        db.apply(out.txn, 12 * SECOND);
        let run = &db.dag_runs[&("c".into(), 1)];
        assert_eq!(run.state, RunState::Success);
        assert_eq!(run.end, Some(12 * SECOND));
    }

    #[test]
    fn retry_rescheduled_then_failed_run() {
        let mut spec = chain_dag("c", 1, 10.0, 5.0);
        spec.tasks[0].retries = 1;
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        advance(&mut db, "c", 1, 0); // queue the root
        let key = ("c".to_string(), 1, 0u32);
        // First try fails -> UpForRetry.
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key: key.clone(), state: TiState::UpForRetry });
        db.apply(t, 2);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::UpForRetry,
        }];
        let out = scheduling_pass(&db, 3, &msg, &SchedLimits::default());
        assert_eq!(out.stats.retries, 1);
        db.apply(out.txn, 3);
        assert_eq!(db.task_instances[&key].state, TiState::Queued);
        // Second try fails terminally.
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Failed });
        db.apply(t, 5);
        let msg = vec![SchedMsg::TaskFinished {
            dag_id: "c".into(),
            run_id: 1,
            task_id: 0,
            state: TiState::Failed,
        }];
        let out = scheduling_pass(&db, 6, &msg, &SchedLimits::default());
        assert_eq!(out.stats.runs_completed, 1);
        db.apply(out.txn, 6);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Failed);
    }

    #[test]
    fn run_of_deleted_dag_is_failed_not_stuck() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        db.apply(out.txn, 0);
        // The DAG disappears (DELETE raced the run-creation txn) while the
        // run's change event is still in flight.
        db.serialized.remove("c");
        db.dags.remove("c");
        let stats = advance(&mut db, "c", 1, 2);
        assert_eq!(stats.runs_completed, 1);
        assert_eq!(db.dag_runs[&("c".into(), 1)].state, RunState::Failed);
        // Terminal now: later passes leave it alone.
        let stats = advance(&mut db, "c", 1, 3);
        assert_eq!(stats.runs_completed, 0);
    }

    #[test]
    fn unknown_dag_ignored() {
        let db = MetaDb::new();
        let out = scheduling_pass(&db, 0, &periodic("ghost"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
        assert!(out.txn.is_empty());
    }

    #[test]
    fn paused_dag_not_run() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        db.dags.get_mut("c").unwrap().is_paused = true;
        let out = scheduling_pass(&db, 0, &periodic("c"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
    }

    #[test]
    fn max_active_runs_gates_triggers() {
        let spec = chain_dag("slow", 1, 10.0, 5.0).max_active_runs(1);
        let mut db = db_with(&spec);
        // First trigger creates a run.
        let out = scheduling_pass(&db, 0, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
        db.apply(out.txn, 0);
        // Second trigger while run 1 is active: skipped.
        let out = scheduling_pass(&db, 1, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 0);
        assert_eq!(out.stats.runs_skipped, 1);
        // Complete run 1, then the next trigger goes through.
        advance(&mut db, "slow", 1, 2);
        let key = ("slow".to_string(), 1, 0u32);
        let mut t = Txn::new();
        t.push(Write::SetTiState { key: key.clone(), state: TiState::Running });
        t.push(Write::SetTiState { key, state: TiState::Success });
        db.apply(t, 3);
        advance(&mut db, "slow", 1, 4); // marks run terminal
        let out = scheduling_pass(&db, 5, &periodic("slow"), &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 1);
    }

    #[test]
    fn two_periodics_same_pass_get_distinct_runs() {
        let spec = chain_dag("c", 1, 10.0, 5.0);
        let mut db = db_with(&spec);
        let batch = vec![
            SchedMsg::Periodic { dag_id: "c".into(), logical_ts: 0 },
            SchedMsg::Periodic { dag_id: "c".into(), logical_ts: 1 },
        ];
        let out = scheduling_pass(&db, 0, &batch, &SchedLimits::default());
        assert_eq!(out.stats.runs_created, 2);
        db.apply(out.txn, 0);
        assert_eq!(db.dag_runs.len(), 2);
        assert!(db.dag_runs.contains_key(&("c".into(), 1)));
        assert!(db.dag_runs.contains_key(&("c".into(), 2)));
    }
}
