#![allow(dead_code)]

//! Shared bench scaffolding: run (system × workload) cells, print
//! paper-style rows, save JSON reports.

use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::metrics::MetricsReport;
use sairflow::util::json::Json;

/// Seeds used for every bench (paper-style repetitions).
pub const SEEDS: [u64; 3] = [11, 23, 47];

/// Run one cell for each seed and pool the reports into one.
pub fn run_cell(
    label: &str,
    system: SystemKind,
    dags: Vec<sairflow::dag::DagSpec>,
    t_minutes: f64,
    warm: bool,
) -> (MetricsReport, Vec<exp::ExperimentResult>) {
    let mut pooled = sairflow::metrics::MetricsSink::new();
    let mut results = Vec::new();
    for seed in SEEDS {
        let spec = ExperimentSpec {
            label: format!("{label} seed={seed}"),
            system: system.clone(),
            dags: dags.clone(),
            seed,
            horizon: ExperimentSpec::paper_horizon(t_minutes),
            skip_first_run: warm,
        };
        let res = exp::run(&spec);
        // Pool observations across seeds (offset run ids to keep them
        // distinct per seed).
        for mut t in res.sink.tasks.clone() {
            t.run_id += seed * 10_000;
            pooled.tasks.push(t);
        }
        for mut r in res.sink.runs.clone() {
            r.run_id += seed * 10_000;
            pooled.runs.push(r);
        }
        results.push(res);
    }
    // skip_first_run was already applied per seed inside exp::run's report;
    // for the pooled report, drop each seed's first run the same way.
    let report = MetricsReport::build(label, &pooled, warm);
    (report, results)
}

/// Paper-style comparison row.
pub fn print_pair(tag: &str, sairflow: &MetricsReport, mwaa: &MetricsReport) {
    println!(
        "{tag:<22} makespan med  sAirflow {:>8.2} s   MWAA {:>8.2} s   ratio {:>5.2}x",
        sairflow.makespan.median,
        mwaa.makespan.median,
        mwaa.makespan.median / sairflow.makespan.median.max(1e-9),
    );
    println!(
        "{:<22} task wait med sAirflow {:>8.2} s   MWAA {:>8.2} s",
        "", sairflow.task_wait.median, mwaa.task_wait.median
    );
    println!(
        "{:<22} task dur med  sAirflow {:>8.2} s   MWAA {:>8.2} s",
        "", sairflow.task_duration.median, mwaa.task_duration.median
    );
}

/// Save a bench report under reports/.
pub fn save(name: &str, body: Json) {
    match exp::save_report(name, &body) {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("report write failed: {e}"),
    }
}

pub fn pair_json(s: &MetricsReport, m: &MetricsReport) -> Json {
    Json::obj().set("sairflow", s.to_json()).set("mwaa", m.to_json())
}
