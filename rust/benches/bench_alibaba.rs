//! Fig. 5 / Figs. 12–15 (Appendix D): the 30 Alibaba-trace-like DAGs.
//!
//! Protocol (App. D): T = 5 min for DAGs with critical path ≤ 200 s,
//! T = 10 min otherwise; sAirflow results include the first (cold) run;
//! MWAA runs warm.
//!
//! Paper results: overall makespans are similar (scatter hugs the
//! diagonal); sAirflow's DAG overhead is ~10% higher, dominated by task
//! duration overheads; after Eq. 1 normalization (× n_L/n_W), MWAA wins
//! on linear DAGs and sAirflow on parallelizable ones.

mod common;

use sairflow::dag::graph::DagGraph;
use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::sim::time::as_secs;
use sairflow::util::json::Json;
use sairflow::util::stats::{linfit, Summary};
use sairflow::workloads::alibaba;

fn main() {
    println!("== Fig 5 / Figs 12-15: Alibaba-like DAGs (30) ==");
    let set = alibaba::alibaba_set(20240501, 30);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    for d in &set {
        let t = alibaba::period_minutes_for(d);
        let spec = d.clone().every_minutes(t);
        let g = DagGraph::of(d);
        let cp = as_secs(g.critical_path_duration());
        let norm = g.parallelizability_factor();

        let sa = exp::run(&ExperimentSpec {
            label: format!("sairflow {}", d.dag_id),
            system: SystemKind::Sairflow,
            dags: vec![spec.clone()],
            seed: 3,
            horizon: ExperimentSpec::paper_horizon(t),
            skip_first_run: false, // paper includes sAirflow's cold run
        });
        let mw = exp::run(&ExperimentSpec {
            label: format!("mwaa {}", d.dag_id),
            system: SystemKind::Mwaa { warm: true },
            dags: vec![spec],
            seed: 3,
            horizon: ExperimentSpec::paper_horizon(t),
            skip_first_run: false,
        });
        let (s_mk, m_mk) = (sa.report.makespan.median, mw.report.makespan.median);
        rows.push((
            d.dag_id,
            cp,
            norm,
            s_mk,
            m_mk,
            sa.report.duration_overhead.mean,
            mw.report.duration_overhead.mean,
        ));
        json_rows.push(
            Json::obj()
                .set("dag", d.dag_id.as_str())
                .set("critical_path_s", cp)
                .set("nl_over_nw", norm)
                .set("sairflow_makespan", s_mk)
                .set("mwaa_makespan", m_mk)
                .set("sairflow_dur_overhead", sa.report.duration_overhead.mean)
                .set("mwaa_dur_overhead", mw.report.duration_overhead.mean),
        );
    }

    println!(
        "{:<14} {:>8} {:>7} | {:>9} {:>9} | {:>8} {:>8} | {:>9} {:>9}",
        "dag", "crit[s]", "nL/nW", "sA mk[s]", "MW mk[s]", "sA ovh", "MW ovh", "sA norm", "MW norm"
    );
    for (id, cp, norm, s, m, so, mo) in &rows {
        println!(
            "{:<14} {:>8.1} {:>7.2} | {:>9.1} {:>9.1} | {:>8.2} {:>8.2} | {:>9.1} {:>9.1}",
            id, cp, norm, s, m, so, mo, (s - cp) * norm, (m - cp) * norm
        );
    }

    // Fig 5a: scatter trend line (sAirflow vs MWAA makespans).
    let xs: Vec<f64> = rows.iter().map(|r| r.4).collect(); // MWAA
    let ys: Vec<f64> = rows.iter().map(|r| r.3).collect(); // sAirflow
    let (a, b) = linfit(&xs, &ys);
    println!("\nFig 5a trend: sairflow ≈ {a:.1} + {b:.2} * mwaa  (paper: slope ≈ 1)");

    // Fig 13a: DAG overhead (makespan − critical path).
    let s_ovh = Summary::of(&rows.iter().map(|r| r.3 - r.1).collect::<Vec<_>>());
    let m_ovh = Summary::of(&rows.iter().map(|r| r.4 - r.1).collect::<Vec<_>>());
    println!("Fig 13a DAG overhead  : sAirflow {}", s_ovh.line());
    println!("                        MWAA     {}", m_ovh.line());
    println!(
        "sAirflow overhead / MWAA overhead = {:.2} (paper: ~10% higher)",
        s_ovh.mean / m_ovh.mean.max(1e-9)
    );

    // Fig 14: normalized overhead (Eq. 1): who wins where.
    let mut s_wins_parallel = 0;
    let mut m_wins_linear = 0;
    for (_, cp, norm, s, m, _, _) in &rows {
        let (sn, mn) = ((s - cp) * norm, (m - cp) * norm);
        if *norm < 1.0 && sn < mn {
            s_wins_parallel += 1; // parallelizable DAG, sAirflow better
        }
        if *norm > 2.0 && mn < sn {
            m_wins_linear += 1; // linear DAG, MWAA better
        }
    }
    println!(
        "Fig 14 normalized: sAirflow wins on {s_wins_parallel} parallelizable DAGs; \
         MWAA wins on {m_wins_linear} linear DAGs"
    );

    common::save(
        "fig5_fig12_15_alibaba",
        Json::obj()
            .set("rows", Json::Arr(json_rows))
            .set("trend_intercept", a)
            .set("trend_slope", b)
            .set("sairflow_overhead_mean", s_ovh.mean)
            .set("mwaa_overhead_mean", m_ovh.mean),
    );
}
