//! Fig. 3 / Fig. 7: parallel DAGs, function executor, **cold starts**
//! (p = 10 s, T = 30 min, n ∈ {16, 32, 64, 125}).
//!
//! Paper result: sAirflow scales out in seconds (makespan < 1 min even at
//! n = 125) while MWAA pays its 4–5 min worker provisioning — makespan
//! reduced by ~1.9× (n=16) up to ~7.2× (n=125). Gantt charts show MWAA
//! packing tasks onto one worker while sAirflow fans out.

mod common;

use sairflow::exp::SystemKind;
use sairflow::metrics::gantt;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::parallel_dag;

fn main() {
    println!("== Fig 3/7: parallel DAGs, cold (p=10, T=30) ==");
    let mut out = Json::obj();
    for n in [16u32, 32, 64, 125] {
        let dags = vec![parallel_dag("parallel", n, 10.0, 30.0)];
        let fp_dags = vec![parallel_dag("parallel", n, 10.0, 30.0).fastpath(true)];
        let (s_rep, s_res) =
            common::run_cell(&format!("sairflow n={n}"), SystemKind::Sairflow, dags.clone(), 30.0, false);
        // PR 10 cell: every fan-out task's only upstream is the root, so
        // the root's completion callback dispatches the whole fan in one
        // shot — the saving is one CDC hop off the makespan (the cold-start
        // provisioning still dominates), not per-task like the chain bench.
        let (f_rep, _) = common::run_cell(
            &format!("sairflow+fastpath n={n}"),
            SystemKind::Sairflow,
            fp_dags,
            30.0,
            false,
        );
        let (m_rep, _) =
            common::run_cell(&format!("mwaa n={n}"), SystemKind::Mwaa { warm: false }, dags, 30.0, false);
        common::print_pair(&format!("n={n}"), &s_rep, &m_rep);
        println!(
            "{:<22} fast path on  makespan med {:>8.2} s (off {:>8.2} s)",
            "", f_rep.makespan.median, s_rep.makespan.median
        );
        out = out.set(&format!("n{n}"), common::pair_json(&s_rep, &m_rep));
        out = out.set(&format!("n{n}_fastpath"), f_rep.to_json());

        if n == 125 {
            // Gantt of a single sAirflow run (the paper's right panels).
            let sink = &s_res[0].sink;
            if let Some(run) = sink.runs.first() {
                let tasks = sink.tasks_of(&run.dag_id, run.run_id);
                println!("\nsAirflow Gantt, n=125, one run ({} workers):", tasks.len());
                println!("{}", gantt::render(&tasks, 90));
            }
            let peak = s_res[0]
                .extras
                .get("worker_concurrent_peak")
                .and_then(|v| v.as_u64())
                .unwrap_or(0);
            println!("sAirflow worker concurrency peak: {peak} (paper: scales to 125)");
        }
    }
    common::save("fig3_fig7_cold_parallel", out);
}
