//! Tables 1–6 (§6.4, Appendix F): the monetary cost comparison.
//!
//! Prints the fixed-cost inventory (Table 6), the per-scenario serverless
//! breakdowns (Tables 2–5) and the MWAA-vs-sAirflow summary (Table 1);
//! additionally prices an *actual simulated run* from its platform
//! counters (the measured counterpart of the analytic tables).

mod common;

use sairflow::cost::{
    self, fixed_components, mwaa_fixed_daily, sairflow_breakdown, sairflow_fixed_daily,
    scenarios, table1, Pricing,
};
use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::parallel_dag;

fn main() {
    let p = Pricing::default();

    println!("== Table 6: sAirflow fixed components (daily $) ==");
    for (name, spec, daily, ha) in fixed_components() {
        println!("  {name:<10} {daily:>6.2}  (HA {ha:>5.2})  {spec}");
    }
    println!(
        "  {:<10} {:>6.2}  (HA {:>5.2})   [paper: 3.92 / 6.03; MWAA fixed: {:.2}]",
        "TOTAL",
        sairflow_fixed_daily(false),
        sairflow_fixed_daily(true),
        mwaa_fixed_daily(&p)
    );

    println!("\n== Tables 2-5: per-scenario serverless breakdowns ==");
    let paper_totals = [
        ("heavy", 1.2677),
        ("distributed", 1.4349),
        ("sporadic", 0.0145),
        ("constant", 29.6521),
    ];
    let mut json = Json::obj();
    for s in scenarios() {
        let rows = sairflow_breakdown(&s, &p);
        let total = cost::total(&rows);
        let paper = paper_totals.iter().find(|(n, _)| *n == s.name).map(|(_, v)| *v).unwrap();
        println!("-- scenario {} (paper total {:.4}, ours {:.4}) --", s.name, paper, total);
        print!("{}", cost::render(&rows));
        json = json.set(
            s.name,
            Json::obj().set("total", total).set("paper_total", paper),
        );
    }

    println!("\n== Table 1: daily totals ==");
    println!(
        "  {:<14} {:>4}  {:>7} {:>7} {:>7}   {:>7} {:>7} {:>7}  {:>6}",
        "scenario", "exec", "M.fix", "M.work", "M.tot", "s.fix", "s.exec", "s.tot", "saving"
    );
    for r in table1(&p) {
        println!(
            "  {:<14} {:>4}  {:>7.2} {:>7.2} {:>7.2}   {:>7.2} {:>7.2} {:>7.2}  {:>5.0}%",
            r.scenario,
            r.executor.name(),
            r.mwaa_fixed,
            r.mwaa_workers,
            r.mwaa_total,
            r.sairflow_fixed,
            r.sairflow_exec,
            r.sairflow_total,
            r.saving * 100.0
        );
    }
    println!("  (paper: totals 12.26/7.30|6.92, 13.74/7.47, 11.76/6.05, 43.44/35.69; savings 17-48%)");

    // Measured: price a simulated heavy-ish run from its platform counters.
    println!("\n== Measured: pricing a simulated run (parallel n=50, p=180 s, T=3... scaled) ==");
    let spec = ExperimentSpec {
        label: "cost-measured".into(),
        system: SystemKind::Sairflow,
        dags: vec![parallel_dag("heavyish", 50, 30.0, 5.0)],
        seed: 5,
        horizon: ExperimentSpec::paper_horizon(5.0),
        skip_first_run: false,
    };
    let res = exp::run(&spec);
    let hours = 75.0 / 60.0;
    let rows = cost::cost_from_sim(&res.extras, hours, &p);
    print!("{}", cost::render(&rows));
    json = json.set("measured_run_total", cost::total(&rows));
    common::save("tab1_6_cost", json);
}
