//! Fig. 6: a single-task DAG (chain n = 1, p = 10, T = 5), function
//! executor — the cold-start anatomy.
//!
//! Paper result: the first (cold) run's task wait is ~12 s; warm runs'
//! median wait is ~2.5 s. The first run is the outlier in the figure.

mod common;

use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::chain_dag;

fn main() {
    println!("== Fig 6: single-task DAG (p=10, T=5), per-run waits ==");
    let mut cold_waits = Vec::new();
    let mut warm_waits: Vec<f64> = Vec::new();
    for seed in common::SEEDS {
        let spec = ExperimentSpec {
            label: format!("single seed={seed}"),
            system: SystemKind::Sairflow,
            dags: vec![chain_dag("one", 1, 10.0, 5.0)],
            seed,
            horizon: ExperimentSpec::paper_horizon(5.0),
            skip_first_run: false,
        };
        let res = exp::run(&spec);
        let mut by_run: Vec<(u64, f64, f64)> = res
            .sink
            .tasks
            .iter()
            .map(|t| (t.run_id, t.wait(), t.duration()))
            .collect();
        by_run.sort_by_key(|(r, _, _)| *r);
        for (i, (run, wait, dur)) in by_run.iter().enumerate() {
            if i == 0 {
                cold_waits.push(*wait);
            } else {
                warm_waits.push(*wait);
            }
            if seed == common::SEEDS[0] {
                println!(
                    "  run {run:>2}: wait {wait:>6.2} s  duration {dur:>6.2} s{}",
                    if i == 0 { "   <- cold start" } else { "" }
                );
            }
        }
    }
    let cold = sairflow::util::stats::Summary::of(&cold_waits);
    let warm = sairflow::util::stats::Summary::of(&warm_waits);
    println!("\ncold-run wait: {}", cold.line());
    println!("warm-run wait: {}", warm.line());
    println!(
        "paper: cold ≈ 12 s, warm median ≈ 2.5 s; measured cold med {:.1} s, warm med {:.1} s",
        cold.median, warm.median
    );
    common::save(
        "fig6_single_task",
        Json::obj()
            .set("cold_wait_median", cold.median)
            .set("warm_wait_median", warm.median)
            .set("cold_runs", cold.n)
            .set("warm_runs", warm.n),
    );
}
