//! Fig. 16 (Appendix E.1): a single-task DAG on the **container executor**
//! (chain n = 1, p = 10, T = 5).
//!
//! Paper result: replacing Lambda with Batch/Fargate raises the median
//! task wait from ~2.5 s to ~100.5 s (provisioning + image start-up), but
//! the task *duration* is ~1 s shorter (0.5 vCPU vs ~0.2 vCPU).

mod common;

use sairflow::exp::SystemKind;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::{chain_dag, chain_dag_caas};

fn main() {
    println!("== Fig 16: single-task DAG on CaaS (p=10, T=5) ==");
    let caas = vec![chain_dag_caas("cc", 1, 10.0, 5.0)];
    let faas = vec![chain_dag("cf", 1, 10.0, 5.0)];

    let (caas_rep, _) = common::run_cell("sairflow caas", SystemKind::Sairflow, caas, 5.0, false);
    let (faas_rep, _) = common::run_cell("sairflow faas", SystemKind::Sairflow, faas.clone(), 5.0, true);
    let (mwaa_rep, _) = common::run_cell("mwaa", SystemKind::Mwaa { warm: true }, faas, 5.0, true);

    println!(
        "task wait med  : CaaS {:>8.2} s | FaaS {:>8.2} s | MWAA {:>8.2} s  (paper: 100.5 / 2.5 / ~1.5)",
        caas_rep.task_wait.median, faas_rep.task_wait.median, mwaa_rep.task_wait.median
    );
    println!(
        "task dur med   : CaaS {:>8.2} s | FaaS {:>8.2} s | MWAA {:>8.2} s  (paper: CaaS ~1 s shorter than FaaS)",
        caas_rep.task_duration.median, faas_rep.task_duration.median, mwaa_rep.task_duration.median
    );
    common::save(
        "fig16_caas_chain",
        Json::obj()
            .set("caas", caas_rep.to_json())
            .set("faas", faas_rep.to_json())
            .set("mwaa", mwaa_rep.to_json()),
    );
}
