//! Fig. 4a / Fig. 8: chain DAGs, function executor, **warm starts**
//! (p = 10 s, T = 5 min, n ∈ {1, 5, 10}; first DAG run not reported).
//!
//! Paper result: sAirflow is ~0.8 s/task slower than MWAA — the price of
//! CDC propagation (each task handoff crosses the DB→DMS→Kinesis path
//! twice), visible as task wait ≈ 2.5 s vs MWAA's ≈ 1.5 s.

mod common;

use sairflow::exp::SystemKind;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::chain_dag;

fn main() {
    println!("== Fig 4a/8: chain DAGs, warm (p=10, T=5) ==");
    let mut out = Json::obj();
    for n in [1u32, 5, 10] {
        let dags = vec![chain_dag("chain", n, 10.0, 5.0)];
        let fp_dags = vec![chain_dag("chain", n, 10.0, 5.0).fastpath(true)];
        let (s_rep, _) =
            common::run_cell(&format!("sairflow n={n}"), SystemKind::Sairflow, dags.clone(), 5.0, true);
        let (f_rep, _) = common::run_cell(
            &format!("sairflow+fastpath n={n}"),
            SystemKind::Sairflow,
            fp_dags,
            5.0,
            true,
        );
        let (m_rep, _) =
            common::run_cell(&format!("mwaa n={n}"), SystemKind::Mwaa { warm: true }, dags, 5.0, true);
        common::print_pair(&format!("chain n={n}"), &s_rep, &m_rep);
        let per_task_delta = (s_rep.makespan.median - m_rep.makespan.median) / n as f64;
        println!(
            "{:<22} per-task delta {:+.2} s/task (paper: sAirflow ~0.8 s slower)",
            "", per_task_delta
        );
        // PR 10: the dataflow fast path removes the CDC hop from every
        // chain edge — the exact overhead the paper charges to sAirflow.
        println!(
            "{:<22} fast path on  makespan med {:>8.2} s ({:+.2} s/task vs off)\n",
            "",
            f_rep.makespan.median,
            (f_rep.makespan.median - s_rep.makespan.median) / n as f64,
        );
        out = out.set(&format!("n{n}"), common::pair_json(&s_rep, &m_rep));
        out = out.set(&format!("n{n}_fastpath"), f_rep.to_json());
    }
    common::save("fig4a_fig8_warm_chain", out);
}
