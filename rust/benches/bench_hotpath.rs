//! L3 performance harness (§Perf in EXPERIMENTS.md): wall-clock profiling
//! of the coordinator hot paths. Not a paper figure — this is the
//! performance-optimization deliverable's measurement tool.
//!
//! Measures (all wall-clock, release build):
//!   1. raw DES event throughput (events/s);
//!   2. metadata-DB commit throughput under a burst;
//!   3. scheduling-pass latency on a large database snapshot;
//!   3b. scheduling-pass latency on a *multi-tenant* snapshot (many DAGs
//!       across many tenants, mixed backfill/foreground traffic) — the
//!       cell that exercises the tenant-attribution and promotion paths;
//!   4. end-to-end simulated experiment wall time (the n=125 cold cell)
//!      and its events/s;
//!   5. PJRT artifact execution latency (if artifacts are built);
//!   6. allocation profile of the per-shard CDC → Kinesis hand-off: the
//!      whole binary runs under a counting `#[global_allocator]`, and the
//!      steady-state delivery loop is asserted allocation-free per record
//!      (the recycled batch buffer of `cloud/kinesis.rs` — the only
//!      allocation per delivery is the engine's boxed event closure);
//!   7. shard scaling of the partitioned scheduling pass (PR 9): full-batch
//!      vs critical-path pass latency at 1/2/4/8 control-plane shards on
//!      the multi-tenant workload. Run with `--bench9` to save the summary
//!      as `rust/reports/BENCH_9.json` and copy the cells into the
//!      committed trajectory file `reports/BENCH_9.json`;
//!   8. the dataflow fast path (PR 10, docs/FASTPATH.md): a warm 10-task
//!      chain run end-to-end with the per-DAG fast path on vs off, in the
//!      same world. Reports both simulated makespans, the counter-verified
//!      fraction of non-root tasks dispatched directly by workers (the
//!      acceptance bar is ≥ 80%), and the per-edge latency saved against
//!      the modeled CDC → scheduler hop (CDC delay midpoint + scheduler
//!      invoke). Run with `--bench10` to save the summary as
//!      `rust/reports/BENCH_10.json` and copy the cells into the committed
//!      trajectory file `reports/BENCH_10.json`.
//!
//! Cells 2/3/3b are the payoff metric of the symbolized identifier
//! fabric (PR 5): every key the DB commit and the scheduling pass touch
//! is a `Copy` [`DagId`] symbol, so the measured loops perform no string
//! allocation. Run with `--bench5` to save the summary as
//! `rust/reports/BENCH_5.json` (instead of the default
//! `rust/reports/perf_hotpath.json` — reports land relative to the crate
//! root cargo runs from), then copy the cell values into the committed
//! trajectory file `reports/BENCH_5.json` at the repository root.
//!
//! CI smoke mode: `cargo bench --bench bench_hotpath -- --test` runs the
//! same hot paths with tiny iteration counts (compile + run, no stats)
//! and saves the summary as `reports/BENCH_ci.json` — the artifact the CI
//! bench-smoke job uploads so the perf trajectory accumulates data
//! points per merge.

mod common;

use sairflow::cloud::db::{Change, DagRow, MetaDb, Txn, Write};
use sairflow::cloud::kinesis::{delivered, put_records, KinesisHost, KinesisStream};
use sairflow::dag::state::{DagId, RunType, TiState};
use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::scheduler::{scheduling_pass, scheduling_pass_sharded, SchedLimits, SchedMsg};
use sairflow::sim::engine::Sim;
use sairflow::sim::time::SECOND;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::parallel_dag;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Heap-allocation counter for cell 6: every `alloc`/`realloc` in the
/// process bumps `ALLOCS`. The overhead (one relaxed atomic increment) is
/// negligible against the timed cells.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bench_des_throughput(target: u64) -> f64 {
    struct W {
        count: u64,
        target: u64,
    }
    let mut sim: Sim<W> = Sim::new(1);
    let mut w = W { count: 0, target };
    fn tick(sim: &mut Sim<W>, w: &mut W) {
        w.count += 1;
        if w.count < w.target {
            sim.after(1, "tick", tick);
        }
    }
    // 8 interleaved self-scheduling chains.
    for _ in 0..8 {
        sim.soon("start", tick);
    }
    let t0 = Instant::now();
    sim.run(&mut w, 10_000_000);
    let dt = t0.elapsed().as_secs_f64();
    w.count as f64 / dt
}

fn bench_db_commits(n: u64) -> f64 {
    struct W {
        db: sairflow::cloud::db::DbService,
    }
    impl sairflow::cloud::db::DbHost for W {
        fn db(&mut self) -> &mut sairflow::cloud::db::DbService {
            &mut self.db
        }
        fn on_committed(_s: &mut Sim<Self>, _w: &mut Self, _c: Vec<sairflow::cloud::db::Change>) {}
    }
    let mut sim: Sim<W> = Sim::new(2);
    let mut w = W { db: sairflow::cloud::db::DbService::new(Default::default()) };
    // Symbols are interned once at the boundary (as the API layer does);
    // the measured loop only copies them.
    let dags: Vec<DagId> = (0..64).map(|i| DagId::intern(&format!("d{i}"))).collect();
    let t0 = Instant::now();
    for i in 0..n {
        let mut t = Txn::new();
        t.push(Write::InsertTi(sairflow::cloud::db::TiRow {
            dag_id: dags[(i % 64) as usize],
            run_id: i % 16,
            task_id: (i % 1000) as u32,
            state: sairflow::dag::TiState::None,
            try_number: 0,
            ready: None,
            start: None,
            end: None,
            host: None,
            fast_dispatched: false,
        }));
        sairflow::cloud::db::commit(&mut sim, &mut w, t, |_s, _w| {});
    }
    sim.run(&mut w, 10_000_000);
    n as f64 / t0.elapsed().as_secs_f64()
}

fn bench_scheduling_pass(iters: u32) -> (f64, usize) {
    // Large snapshot: 40 DAGs x 80 tasks, half-finished runs.
    let mut db = MetaDb::new();
    let mut msgs = Vec::new();
    for d in 0..40 {
        let spec = parallel_dag(&format!("d{d}"), 80, 10.0, 5.0);
        let dag: DagId = spec.dag_id;
        let mut txn = Txn::new();
        txn.push(Write::UpsertDag(DagRow {
            dag_id: dag,
            fileloc: String::new(),
            period: spec.period,
            is_paused: false,
        }));
        txn.push(Write::PutSerializedDag(spec.clone()));
        db.apply(txn, 0);
        let out = scheduling_pass(
            &db,
            0,
            &[SchedMsg::Trigger { dag_id: dag, logical_ts: 0, run_type: RunType::Scheduled }],
            &SchedLimits { parallelism: 10_000, ..SchedLimits::default() },
        );
        db.apply(out.txn, 0);
        msgs.push(SchedMsg::RunChanged { dag_id: dag, run_id: 1 });
    }
    let t0 = Instant::now();
    let mut total_writes = 0;
    for _ in 0..iters {
        let limits = SchedLimits { parallelism: 10_000, ..SchedLimits::default() };
        let out = scheduling_pass(&db, 1, &msgs, &limits);
        total_writes += out.txn.writes.len();
    }
    let per_pass = t0.elapsed().as_secs_f64() / iters as f64;
    (per_pass * 1e3, total_writes / iters as usize)
}

/// Cell 3b: a multi-tenant snapshot — `tenants` tenants × `dags_per`
/// DAGs × 30 tasks, with mixed traffic per pass: foreground run events
/// plus a backfill trigger wave, so the pass exercises per-tenant budget
/// accounting, the promotion queue and backfill dedup alongside the
/// plain scheduling path. Symbols make the tenant attribution a field
/// read per row; pre-symbol code re-split every id per check.
fn bench_scheduling_pass_multitenant(iters: u32, tenants: u32, dags_per: u32) -> (f64, usize) {
    let (db, msgs) = build_multitenant_snapshot(1, tenants, dags_per);
    let limits = SchedLimits { parallelism: 100_000, ..SchedLimits::default() };
    let t0 = Instant::now();
    let mut total_writes = 0;
    for _ in 0..iters {
        let out = scheduling_pass(&db, 1, &msgs, &limits);
        total_writes += out.txn.writes.len();
    }
    let per_pass = t0.elapsed().as_secs_f64() / iters as f64;
    (per_pass * 1e3, total_writes / iters.max(1) as usize)
}

/// The multi-tenant snapshot behind cells 3b and 7, at a chosen shard
/// count: `tenants` × `dags_per` DAGs × 30 tasks with one running
/// foreground run each, plus the mixed per-pass message batch.
fn build_multitenant_snapshot(
    n_shards: usize,
    tenants: u32,
    dags_per: u32,
) -> (MetaDb, Vec<SchedMsg>) {
    let mut db = MetaDb::with_shards(n_shards);
    let mut msgs = Vec::new();
    for t in 0..tenants {
        let tenant = format!("tenant{t:02}");
        for d in 0..dags_per {
            let local = format!("dag{d:02}");
            let mut spec = parallel_dag(&local, 30, 10.0, 5.0);
            spec.dag_id = DagId::scoped(&tenant, &local);
            let dag: DagId = spec.dag_id;
            let mut txn = Txn::new();
            txn.push(Write::UpsertDag(DagRow {
                dag_id: dag,
                fileloc: String::new(),
                period: spec.period,
                is_paused: false,
            }));
            txn.push(Write::PutSerializedDag(spec.clone()));
            db.apply(txn, 0);
            let out = scheduling_pass(
                &db,
                0,
                &[SchedMsg::Trigger { dag_id: dag, logical_ts: 0, run_type: RunType::Scheduled }],
                &SchedLimits { parallelism: 100_000, ..SchedLimits::default() },
            );
            db.apply(out.txn, 0);
            msgs.push(SchedMsg::RunChanged { dag_id: dag, run_id: 1 });
            // A backfill wave per DAG: the k=0 date collides with the
            // scheduled run above (dedup path), the other three are
            // fresh (creation + promotion-budget path).
            for k in 0..4u64 {
                msgs.push(SchedMsg::Trigger {
                    dag_id: dag,
                    logical_ts: k * 60_000_000,
                    run_type: RunType::Backfill,
                });
            }
        }
    }
    (db, msgs)
}

/// Cell 7: shard scaling of the partitioned scheduling pass (PR 9). For
/// each shard count, the *full batch* pass measures total work (flat by
/// construction — partitioning adds no per-message overhead), and the
/// *critical path* measures the slowest single shard fed only its own
/// slice of the batch: the wall-clock of a deployment running one
/// scheduler lambda per shard (`world.rs`'s single-lambda sweep is the
/// sequential degenerate case). Near-linear scaling means critical path
/// ≈ t₁/n until the shared floor — the global promotion FIFO drain and
/// budget accounting each lambda repeats — dominates. Returns
/// `(n_shards, full_ms, critical_path_ms)` per shard count.
fn bench_shard_scaling(iters: u32, tenants: u32, dags_per: u32) -> Vec<(usize, f64, f64)> {
    let limits = SchedLimits { parallelism: 100_000, ..SchedLimits::default() };
    let mut cells = Vec::new();
    for n in [1usize, 2, 4, 8] {
        let (db, msgs) = build_multitenant_snapshot(n, tenants, dags_per);
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = scheduling_pass_sharded(&db, 1, &msgs, &limits, n);
        }
        let full_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
        let mut critical_ms = 0.0f64;
        for s in 0..n {
            let part: Vec<SchedMsg> =
                msgs.iter().copied().filter(|m| m.shard_of(n) == s).collect();
            let t0 = Instant::now();
            for _ in 0..iters {
                let _ = scheduling_pass_sharded(&db, 1, &part, &limits, n);
            }
            critical_ms = critical_ms.max(t0.elapsed().as_secs_f64() / iters as f64 * 1e3);
        }
        cells.push((n, full_ms, critical_ms));
    }
    cells
}

/// Cell 6: steady-state allocation profile of the per-shard CDC →
/// Kinesis hand-off. One shard is pre-loaded with `total` change records
/// (all allocation up front), then the serialized delivery loop drains
/// it: take the recycled batch buffer, fill it from the ring, hand it to
/// the consumer, get it back via `delivered`. After warm-up the loop's
/// only allocation is the engine's boxed event closure — exactly one per
/// delivery, zero per record (`Change` is `Copy`, the buffer never
/// regrows). Returns (allocs/delivery, allocs/record, records/s).
fn bench_cdc_handoff(total: u64) -> (f64, f64, f64) {
    struct W {
        k: KinesisStream<Change>,
    }
    impl KinesisHost for W {
        type Record = Change;
        fn kinesis(&mut self) -> &mut KinesisStream<Change> {
            &mut self.k
        }
        fn on_records(sim: &mut Sim<Self>, w: &mut Self, shard: usize, records: Vec<Change>) {
            // The pre-parse consumer reads records by value (`Copy`) and
            // hands the buffer straight back for recycling.
            delivered(sim, w, shard, records);
        }
    }
    let mut sim: Sim<W> = Sim::new(11);
    let mut w = W { k: KinesisStream::new(1) };
    let dag = DagId::intern("cdc-handoff-bench");
    let records: Vec<Change> = (0..total)
        .map(|i| Change::Ti {
            dag_id: dag,
            run_id: i % 16,
            task_id: (i % 100) as u32,
            state: TiState::Queued,
        })
        .collect();
    put_records(&mut sim, &mut w, 0, records);
    // Warm-up: the first deliveries grow the spare buffer and event heap.
    sim.run_until(&mut w, 2 * SECOND, 10_000_000);
    let batches0 = w.k.stats.batches;
    let out0 = w.k.stats.records_out;
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    sim.run(&mut w, 10_000_000);
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64;
    let deliveries = (w.k.stats.batches - batches0) as f64;
    let recs = (w.k.stats.records_out - out0) as f64;
    assert_eq!(w.k.stats.records_out, total, "every record delivered");
    assert!(deliveries > 0.0 && recs > 0.0, "measured window must not be empty");
    (allocs / deliveries, allocs / recs, recs / dt)
}

/// Cell 8: the dataflow fast path (PR 10, docs/FASTPATH.md) on a warm
/// n-task chain — the workload whose every edge is unambiguous, i.e. the
/// fast path's best case and the paper's Fig. 4a shape. The same world
/// runs the chain with the per-DAG flag off (every hand-off pays the
/// CDC → Kinesis → scheduler-pass hop) and on (workers queue the
/// successor from the completion callback). Returns
/// `(makespan_off_s, makespan_on_s, dispatched, dispatch_frac)`; the
/// dispatch counters come from the per-shard operator gauges, so the
/// reported fraction is exactly what `/api/v1/health` would show.
fn bench_fastpath_chain(n: u32) -> (f64, f64, u64, f64) {
    use sairflow::dag::state::RunState;
    use sairflow::sairflow::{trigger_dag, upload_dag, Config, World};
    use sairflow::sim::time::{as_secs, MINUTE};
    use sairflow::workloads::synthetic::chain_dag;

    fn run_chain(n: u32, fast: bool) -> (f64, u64, u64) {
        let w = World::new(Config::seeded(11));
        let mut sim = w.sim();
        let mut w = w;
        let mut spec = chain_dag("fp_chain", n, 1.0, 5.0).fastpath(fast);
        spec.period = None; // manual trigger only: one run, clean makespan
        upload_dag(&mut sim, &mut w, &spec);
        sim.run_until(&mut w, MINUTE, 10_000_000);
        trigger_dag(&mut sim, &mut w, "fp_chain");
        sim.run_until(&mut w, 60 * MINUTE, 10_000_000);
        let run = w
            .db
            .read()
            .dag_runs
            .values()
            .next()
            .cloned()
            .expect("the triggered run exists");
        assert_eq!(run.state, RunState::Success, "chain must finish (fast={fast})");
        let makespan = as_secs(run.end.unwrap() - run.start.unwrap());
        let dispatched = w.shard_passes.iter().map(|p| p.fastpath_dispatched).sum();
        let fallback = w.shard_passes.iter().map(|p| p.fastpath_fallback).sum();
        (makespan, dispatched, fallback)
    }

    let (off_s, off_disp, _) = run_chain(n, false);
    assert_eq!(off_disp, 0, "fast path off must never dispatch directly");
    let (on_s, on_disp, on_fb) = run_chain(n, true);
    let edges = (n - 1) as f64;
    let frac = on_disp as f64 / edges.max(1.0);
    assert!(
        frac >= 0.8,
        "fast path must dispatch >= 80% of non-root tasks directly: \
         {on_disp}/{edges} dispatched, {on_fb} fallbacks"
    );
    assert!(
        on_s < off_s,
        "fast path must shorten the chain: on {on_s:.2} s vs off {off_s:.2} s"
    );
    (off_s, on_s, on_disp, frac)
}

fn bench_e2e(n_tasks: u32) -> (f64, f64) {
    let spec = ExperimentSpec {
        label: "hotpath-e2e".into(),
        system: SystemKind::Sairflow,
        dags: vec![parallel_dag("p", n_tasks, 10.0, 30.0)],
        seed: 7,
        horizon: ExperimentSpec::paper_horizon(30.0),
        skip_first_run: false,
    };
    let t0 = Instant::now();
    let res = exp::run(&spec);
    let wall = t0.elapsed().as_secs_f64();
    assert!(res.report.n_runs >= 3);
    (wall, res.report.makespan.mean)
}

fn main() {
    // CI smoke: tiny iteration counts, no stats — proves the paths run.
    let ci = std::env::args().any(|a| a == "--test" || a == "--ci-smoke");
    let bench5 = std::env::args().any(|a| a == "--bench5");
    let bench9 = std::env::args().any(|a| a == "--bench9");
    let bench10 = std::env::args().any(|a| a == "--bench10");
    let (des_target, db_n, pass_iters, e2e_tasks) =
        if ci { (100_000, 5_000, 5, 16) } else { (2_000_000, 100_000, 200, 125) };
    if ci {
        println!("== L3 hot-path CI smoke (reduced iterations, no stats) ==");
    } else {
        println!("== L3 hot-path performance ==");
    }
    let des = bench_des_throughput(des_target);
    println!("DES event throughput      : {:>12.0} events/s", des);
    let db = bench_db_commits(db_n);
    println!("DB commit throughput      : {:>12.0} commits/s", db);
    let (pass_ms, writes) = bench_scheduling_pass(pass_iters);
    println!("scheduling pass (40x80)   : {pass_ms:>9.3} ms/pass ({writes} writes)");
    let (mt_tenants, mt_dags) = if ci { (4, 4) } else { (20, 10) };
    let (mt_ms, mt_writes) =
        bench_scheduling_pass_multitenant(pass_iters, mt_tenants, mt_dags);
    println!(
        "scheduling pass (mt {mt_tenants}x{mt_dags}) : {mt_ms:>9.3} ms/pass ({mt_writes} writes)"
    );
    // Cell 7: shard scaling on the same multi-tenant workload shape.
    let sc_iters = if ci { 2 } else { 50 };
    let scaling = bench_shard_scaling(sc_iters, mt_tenants, mt_dags);
    let t1_ms = scaling[0].1;
    let mut scaling_json = Vec::new();
    for &(n, full_ms, critical_ms) in &scaling {
        let speedup = t1_ms / critical_ms.max(1e-9);
        println!(
            "sched pass {n} shard(s)    : {full_ms:>9.3} ms full batch, {critical_ms:>9.3} ms critical path ({speedup:.2}x vs 1 shard)"
        );
        scaling_json.push(
            Json::obj()
                .set("n_shards", n as u64)
                .set("full_pass_ms", full_ms)
                .set("critical_path_ms", critical_ms)
                .set("speedup_vs_1_shard", speedup),
        );
    }
    let handoff_total = if ci { 2_000 } else { 50_000 };
    let (ho_per_delivery, ho_per_record, ho_rps) = bench_cdc_handoff(handoff_total);
    println!(
        "CDC hand-off allocations  : {ho_per_delivery:>9.3} /delivery, {ho_per_record:.4} /record ({ho_rps:.0} records/s)"
    );
    // The zero-allocation claim: nothing in the hand-off allocates per
    // record, and per delivery the only allocation is the engine's boxed
    // event closure (plus rare amortized heap growth).
    assert!(
        ho_per_record < 0.5,
        "per-record allocation crept into the CDC hand-off: {ho_per_record} allocs/record"
    );
    assert!(
        ho_per_delivery < 4.0,
        "per-delivery allocations regressed: {ho_per_delivery} (expected ~1: the event closure)"
    );
    // Cell 8: the dataflow fast path on a warm 10-task chain. Simulated
    // time, so it runs in full even in CI smoke — the cell lands in
    // BENCH_ci.json on every merge.
    let fp_n = 10u32;
    let (fp_off_s, fp_on_s, fp_disp, fp_frac) = bench_fastpath_chain(fp_n);
    let fp_edges = (fp_n - 1) as f64;
    let fp_per_edge = (fp_off_s - fp_on_s) / fp_edges;
    // The modeled hop the fast path removes per edge: the CDC delivery
    // delay plus the scheduling-pass CPU, at their distribution midpoints
    // (the scheduler lambda is warm mid-chain, so invoke latency ~0).
    let cfgm = sairflow::sairflow::Config::seeded(11);
    let fp_model =
        (cfgm.cdc_delay.0 + cfgm.cdc_delay.1) / 2.0 + (cfgm.sched_cpu.0 + cfgm.sched_cpu.1) / 2.0;
    println!(
        "fast path chain n={fp_n}    : off {fp_off_s:>7.2} s, on {fp_on_s:>7.2} s \
         ({fp_disp}/{fp_edges:.0} = {:.0}% direct, {fp_per_edge:.2} s/edge saved, \
         modeled hop {fp_model:.2} s)",
        fp_frac * 100.0
    );

    let (e2e_wall, mk) = bench_e2e(e2e_tasks);
    println!("e2e n={e2e_tasks} cold experiment : {e2e_wall:>9.3} s wall (sim makespan {mk:.1} s)");

    let mut json = Json::obj()
        .set("ci_smoke", ci)
        .set("des_events_per_sec", des)
        .set("db_commits_per_sec", db)
        .set("sched_pass_ms", pass_ms)
        .set("sched_pass_multitenant_ms", mt_ms)
        .set("sched_pass_multitenant_tenants", mt_tenants as u64)
        .set("sched_pass_multitenant_dags_per_tenant", mt_dags as u64)
        .set("e2e_tasks", e2e_tasks as u64)
        .set("e2e_wall_secs", e2e_wall)
        .set("cdc_handoff_allocs_per_delivery", ho_per_delivery)
        .set("cdc_handoff_allocs_per_record", ho_per_record)
        .set("cdc_handoff_records_per_sec", ho_rps)
        .set(
            "shard_scaling_workload",
            format!("{mt_tenants} tenants x {mt_dags} dags x 30 tasks"),
        )
        .set("shard_scaling", Json::Arr(scaling_json))
        .set("fastpath_chain_n", fp_n as u64)
        .set("fastpath_makespan_off_s", fp_off_s)
        .set("fastpath_makespan_on_s", fp_on_s)
        .set("fastpath_dispatched", fp_disp)
        .set("fastpath_dispatch_frac", fp_frac)
        .set("fastpath_per_edge_saved_s", fp_per_edge)
        .set("fastpath_modeled_hop_s", fp_model);

    // L1/L2: PJRT execution latency (skipped without artifacts).
    match sairflow::runtime::Engine::load_dir(&sairflow::runtime::default_artifacts_dir()) {
        Ok(mut engine) => {
            for name in engine.artifact_names() {
                // Warm up (compile caches, first-touch), then measure.
                let _ = engine.execute_timed(&name, 3, 0);
                let iters = if ci { 5 } else { 50 };
                let wall = engine.execute_timed(&name, iters, 0).unwrap_or(f64::NAN);
                let per = wall / iters as f64 * 1e6;
                println!("PJRT {name:<28}: {per:>9.1} µs/exec");
                json = json.set(&format!("pjrt_{name}_us"), per);
            }
        }
        Err(_) => println!("PJRT artifacts not built; run `make artifacts`"),
    }
    let report = if ci {
        "BENCH_ci"
    } else if bench10 {
        "BENCH_10"
    } else if bench9 {
        "BENCH_9"
    } else if bench5 {
        "BENCH_5"
    } else {
        "perf_hotpath"
    };
    common::save(report, json);
}
