//! Ablations of sAirflow's design choices (DESIGN.md "Key design
//! decisions") — not a paper table, but the quantified version of the
//! paper's discussion:
//!
//! * §4.2/§6.2: "DMS introduces a significant delay to the control loop"
//!   → sweep the CDC delay from 0 to 3 s and measure the chain per-task
//!   tax. The 0-s point quantifies §7's wish ("ideally, these two
//!   capabilities should be integrated into a single cloud-native
//!   serverless service").
//! * scheduler feed batch size (cost model uses 10): latency vs batching.
//! * worker keep-alive: how long a gap still finds the pool warm (the
//!   T=5 vs T=30 boundary).
//! * database size (servers): the §6.1 contention bottleneck.

mod common;

use sairflow::exp::{self, ExperimentSpec};
use sairflow::sairflow::Config;
use sairflow::sim::time::mins;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::{chain_dag, parallel_dag};

fn run_chain_with(cfg: Config) -> (f64, f64) {
    let dags = vec![chain_dag("c", 10, 10.0, 5.0)];
    let (w, sink) = exp::run_sairflow(cfg, &dags, ExperimentSpec::paper_horizon(5.0));
    let _ = w;
    let rep = sairflow::metrics::MetricsReport::build("ablate", &sink, true);
    (rep.makespan.median, rep.task_wait.median)
}

fn run_parallel_with(cfg: Config, n: u32) -> f64 {
    let dags = vec![parallel_dag("p", n, 10.0, 30.0)];
    let (_, sink) = exp::run_sairflow(cfg, &dags, ExperimentSpec::paper_horizon(30.0));
    let rep = sairflow::metrics::MetricsReport::build("ablate", &sink, false);
    rep.task_duration.p95
}

fn main() {
    let mut out = Json::obj();

    println!("== ablation 1: CDC delay (chain n=10 warm; paper's 1-1.5 s is the tax) ==");
    println!("{:>12} {:>14} {:>12}", "cdc delay", "makespan med", "wait med");
    let mut arr = Vec::new();
    for delay in [0.0, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0] {
        let mut cfg = Config::seeded(7);
        cfg.cdc_delay = (delay * 0.9, (delay * 1.1).max(delay * 0.9 + 1e-6));
        let (mk, wait) = run_chain_with(cfg);
        println!("{delay:>10.2} s {mk:>12.1} s {wait:>10.2} s");
        arr.push(Json::obj().set("delay", delay).set("makespan", mk).set("wait", wait));
    }
    out = out.set("cdc_delay_sweep", Json::Arr(arr));
    println!("(delay→0 is §7's 'cloud-native CDC' wish: the chain tax collapses)");

    println!("\n== ablation 2: scheduler feed batch size ==");
    let mut arr = Vec::new();
    for batch in [1usize, 5, 10, 25] {
        let mut cfg = Config::seeded(7);
        let _ = &mut cfg; // batch size lives in the ESM config at deploy
        let dags = vec![parallel_dag("p", 64, 10.0, 30.0)];
        let mut w = sairflow::sairflow::World::new(cfg);
        w.sched_esm.cfg.batch_size = batch;
        let mut sim = w.sim();
        for d in &dags {
            sairflow::sairflow::upload_dag(&mut sim, &mut w, d);
        }
        sim.run_until(&mut w, ExperimentSpec::paper_horizon(30.0), 50_000_000);
        let sink = exp::collect_sink(w.db.read());
        let rep = sairflow::metrics::MetricsReport::build("b", &sink, false);
        let sched = w.faas.stats(w.fns.scheduler);
        println!(
            "batch {batch:>3}: makespan med {:>7.1} s | scheduler invocations {:>5}",
            rep.makespan.median, sched.invocations
        );
        arr.push(
            Json::obj()
                .set("batch", batch)
                .set("makespan", rep.makespan.median)
                .set("sched_invocations", sched.invocations),
        );
    }
    out = out.set("sched_batch_sweep", Json::Arr(arr));
    println!("(larger batches cut scheduler invocations ~linearly at equal latency)");

    println!("\n== ablation 3: worker keep-alive vs period (the warm/cold boundary) ==");
    let mut arr = Vec::new();
    for keep_min in [2.0, 5.0, 10.0, 20.0, 40.0] {
        let cfg = Config::seeded(7).keep_alive(mins(keep_min));
        let dags = vec![chain_dag("c", 1, 10.0, 15.0)]; // T=15 min
        let (w, sink) = exp::run_sairflow(cfg, &dags, mins(95.0));
        let rep = sairflow::metrics::MetricsReport::build("k", &sink, true);
        let st = w.faas.stats(w.fns.worker);
        println!(
            "keep-alive {keep_min:>4.0} min: warm wait med {:>5.2} s | cold starts {} / {} invocations",
            rep.task_wait.median, st.cold_starts, st.invocations
        );
        arr.push(
            Json::obj()
                .set("keep_alive_min", keep_min)
                .set("wait_med", rep.task_wait.median)
                .set("cold_starts", st.cold_starts),
        );
    }
    out = out.set("keep_alive_sweep", Json::Arr(arr));

    println!("\n== ablation 4: what limits the n=125 burst (task duration p95, p=10 s) ==");
    // 4a: more DB vCPUs do NOT help — the bottleneck is Airflow's
    // run-level lock serialization, not CPU ("the transactional nature of
    // the internal Airflow's code becomes a bottleneck", §6.1).
    let mut arr = Vec::new();
    for servers in [1usize, 2, 8] {
        let mut cfg = Config::seeded(7);
        cfg.db.servers = servers;
        let p95 = run_parallel_with(cfg, 125);
        println!("  db servers {servers}: p95 {p95:>6.1} s  (scaling CPUs doesn't help)");
        arr.push(Json::obj().set("servers", servers).set("dur_p95", p95));
    }
    out = out.set("db_servers_sweep", Json::Arr(arr));
    // 4b: shrinking the serialized completion work (the per-row
    // mini-scheduler scan under the run lock) is the real lever.
    let mut arr = Vec::new();
    for scan_us in [0.0, 100.0, 250.0, 500.0, 1000.0] {
        let mut cfg = Config::seeded(7);
        cfg.db.per_row_scan = scan_us / 1e6;
        let p95 = run_parallel_with(cfg, 125);
        println!("  per-row scan {scan_us:>6.0} µs: p95 {p95:>6.1} s");
        arr.push(Json::obj().set("per_row_scan_us", scan_us).set("dur_p95", p95));
    }
    out = out.set("row_scan_sweep", Json::Arr(arr));
    println!("(the lock-held completion work, not DB size, sets the §6.1 tail)");

    common::save("ablations", out);
}
