//! Fig. 17 (Appendix E.2): parallel DAGs on the **container executor**
//! (p = 10, T = 10, n ∈ {16, 32}; FaaS root + CaaS fan-out) vs cold MWAA.
//!
//! Paper result: slower at n = 16, but at n = 32 sAirflow-on-containers
//! (~140 s) already beats cold-starting MWAA (~160 s) — Batch scales
//! worse than Lambda but still beats the MWAA autoscaler; start-up
//! overhead varies heavily (Batch queueing).

mod common;

use sairflow::exp::SystemKind;
use sairflow::metrics::gantt;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::{parallel_dag, parallel_dag_caas};

fn main() {
    println!("== Fig 17: parallel DAGs on CaaS vs cold MWAA (p=10, T=10) ==");
    let mut out = Json::obj();
    for n in [16u32, 32] {
        let caas = vec![parallel_dag_caas("pc", n, 10.0, 10.0)];
        let faas_equiv = vec![parallel_dag("pm", n, 10.0, 10.0)];
        let (c_rep, c_res) =
            common::run_cell(&format!("sairflow caas n={n}"), SystemKind::Sairflow, caas, 10.0, false);
        let (m_rep, _) = common::run_cell(
            &format!("mwaa cold n={n}"),
            SystemKind::Mwaa { warm: false },
            faas_equiv,
            10.0,
            false,
        );
        println!(
            "n={n:<4} makespan med: sAirflow/CaaS {:>8.2} s | cold MWAA {:>8.2} s   (paper n=32: ~140 vs ~160)",
            c_rep.makespan.median, m_rep.makespan.median
        );
        println!(
            "       wait med {:>6.2} s  wait std {:>6.2} s (heavy Batch variance)",
            c_rep.task_wait.median, c_rep.task_wait.std
        );
        out = out.set(&format!("n{n}"), common::pair_json(&c_rep, &m_rep));

        if n == 32 {
            let sink = &c_res[0].sink;
            if let Some(run) = sink.runs.first() {
                let tasks = sink.tasks_of(&run.dag_id, run.run_id);
                println!("\nsAirflow/CaaS Gantt (one run):");
                println!("{}", gantt::render(&tasks, 90));
            }
        }
    }
    common::save("fig17_caas_parallel", out);
}
