//! Fig. 10 / Fig. 11 (Appendix C): parallel forests — k ∈ {1, 2, 4, 8}
//! copies of a parallel DAG (n = 8, p = 10, T = 5) running concurrently.
//!
//! Paper result: both systems degrade similarly as k grows (k=1: ~20.9 s
//! sAirflow vs 19.6 s MWAA; k=8: ~28.2 vs 23.9); and a forest of k DAGs
//! of n tasks behaves like one DAG with k*n tasks (Fig. 11).

mod common;

use sairflow::exp::SystemKind;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::{parallel_dag, parallel_forest};

fn main() {
    println!("== Fig 10: parallel forest (n=8, p=10, T=5, k copies) ==");
    let mut out = Json::obj();
    for k in [1u32, 2, 4, 8] {
        let dags = parallel_forest("forest", k, 8, 10.0, 5.0);
        let (s_rep, _) =
            common::run_cell(&format!("sairflow k={k}"), SystemKind::Sairflow, dags.clone(), 5.0, true);
        let (m_rep, _) =
            common::run_cell(&format!("mwaa k={k}"), SystemKind::Mwaa { warm: true }, dags, 5.0, true);
        common::print_pair(&format!("forest k={k}"), &s_rep, &m_rep);
        out = out.set(&format!("k{k}"), common::pair_json(&s_rep, &m_rep));
    }

    println!("\n== Fig 11: forest k DAGs × 8 tasks vs single DAG of 8k tasks (sAirflow) ==");
    for k in [2u32, 4, 8] {
        let forest = parallel_forest("forest", k, 8, 10.0, 5.0);
        let single = vec![parallel_dag("single", 8 * k, 10.0, 5.0)];
        let (f_rep, _) =
            common::run_cell(&format!("forest k={k}"), SystemKind::Sairflow, forest, 5.0, true);
        let (s_rep, _) =
            common::run_cell(&format!("single n={}", 8 * k), SystemKind::Sairflow, single, 5.0, true);
        println!(
            "total {:>3} tasks: forest med {:>7.2} s | single-DAG med {:>7.2} s | wait med {:>5.2} vs {:>5.2} s",
            8 * k,
            f_rep.makespan.median,
            s_rep.makespan.median,
            f_rep.task_wait.median,
            s_rep.task_wait.median
        );
        out = out.set(
            &format!("fig11_k{k}"),
            Json::obj().set("forest", f_rep.to_json()).set("single", s_rep.to_json()),
        );
    }
    common::save("fig10_fig11_forest", out);
}
