//! Fig. 4b,c / Fig. 9: parallel DAGs, function executor, **warm starts**
//! (p = 10 s, T = 5 min, n ∈ {16, 32, 64, 125}; MWAA pinned to 25
//! workers; first DAG run not reported).
//!
//! Paper result: comparable at n = 16/32 (MWAA marginally faster at 16);
//! sAirflow faster at n = 64/125, with shorter and less variable task
//! waits (event-driven vs polling).

mod common;

use sairflow::exp::SystemKind;
use sairflow::util::json::Json;
use sairflow::workloads::synthetic::parallel_dag;

fn main() {
    println!("== Fig 4b,c/9: parallel DAGs, warm (p=10, T=5) ==");
    let mut out = Json::obj();
    for n in [16u32, 32, 64, 125] {
        let dags = vec![parallel_dag("parallel", n, 10.0, 5.0)];
        let (s_rep, _) =
            common::run_cell(&format!("sairflow n={n}"), SystemKind::Sairflow, dags.clone(), 5.0, true);
        let (m_rep, _) =
            common::run_cell(&format!("mwaa n={n}"), SystemKind::Mwaa { warm: true }, dags, 5.0, true);
        common::print_pair(&format!("n={n}"), &s_rep, &m_rep);
        println!(
            "{:<22} wait std      sAirflow {:>8.2} s   MWAA {:>8.2} s (variability)\n",
            "", s_rep.task_wait.std, m_rep.task_wait.std
        );
        out = out.set(&format!("n{n}"), common::pair_json(&s_rep, &m_rep));
    }
    common::save("fig4bc_fig9_warm_parallel", out);
}
