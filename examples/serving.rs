//! Serving mode: the coordinator as a long-running, wall-clock service
//! driven entirely through the v1 control-plane API.
//!
//! The DES normally runs in pure virtual time; here a real-time driver
//! paces it against the wall clock (with a configurable speed-up) while
//! Poisson-arriving requests hit the REST surface the way Airflow's
//! webserver would: the DAG is uploaded with `POST /api/v1/dags`, every
//! trigger is a `POST /api/v1/dags/{id}/dagRuns`, and the final report is
//! assembled from `GET .../dagRuns` (a `limit=0` count probe) and
//! `GET /api/v1/health` — demonstrating the rust event loop as an actual
//! service and reporting request→completion latency and throughput.
//!
//! ```sh
//! cargo run --release --example serving -- --rps 2 --duration 30 --speedup 20
//! ```

use sairflow::api::{dispatch, Method};
use sairflow::exp::collect_sink;
use sairflow::sairflow::{Config, World};
use sairflow::sim::time::{as_secs, mins, secs, SimTime};
use sairflow::util::cli::Args;
use sairflow::util::json::Json;
use sairflow::util::rng::Rng;
use sairflow::util::stats::Summary;
use sairflow::workloads::synthetic::parallel_dag;
use std::time::Instant;

fn main() {
    let args = Args::from_env(&[]);
    let rps = args.get_f64("rps", 2.0);
    let wall_duration = args.get_f64("duration", 20.0);
    let speedup = args.get_f64("speedup", 20.0);

    let mut world = World::new(Config::seeded(99));
    let mut sim = world.sim();

    // A manually-triggered workflow (no cron schedule), uploaded through
    // the API like any client would.
    let mut dag = parallel_dag("api_fanout", 8, 2.0, 5.0);
    dag.period = None;
    let body = Json::obj().set("file_text", dag.to_json().to_string_pretty());
    let resp = dispatch(&mut sim, &mut world, Method::Post, "/api/v1/dags", Some(&body));
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "upload failed: {resp}");
    sim.run_until(&mut world, mins(1.0), 1_000_000); // settle parse/CDC

    println!(
        "serving: {rps} req/s for {wall_duration} s wall at {speedup}x speed-up \
         (= {:.0} s simulated)",
        wall_duration * speedup
    );

    // Pre-sample Poisson arrivals in *simulated* time.
    let sim_horizon = secs(wall_duration * speedup);
    let mut arrivals: Vec<SimTime> = Vec::new();
    let mut rng = Rng::new(4242);
    let mut t = sim.now();
    loop {
        t += secs(rng.exponential(speedup / rps));
        if t >= sim.now() + sim_horizon {
            break;
        }
        arrivals.push(t);
    }
    println!("{} requests scheduled", arrivals.len());

    // Real-time pacing loop: advance virtual time in lockstep with the
    // wall clock; inject API triggers when their arrival time passes.
    let start_wall = Instant::now();
    let start_sim = sim.now();
    let mut next_arrival = 0usize;
    let mut request_starts: Vec<(u64, SimTime)> = Vec::new();
    let mut rejected = 0u64;
    loop {
        let wall = start_wall.elapsed().as_secs_f64();
        let target_sim = start_sim + secs(wall * speedup);
        while next_arrival < arrivals.len() && arrivals[next_arrival] <= target_sim {
            let at = arrivals[next_arrival];
            sim.run_until(&mut world, at, 50_000_000);
            let resp = dispatch(
                &mut sim,
                &mut world,
                Method::Post,
                "/api/v1/dags/api_fanout/dagRuns",
                None,
            );
            if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                request_starts.push((next_arrival as u64, at));
            } else {
                rejected += 1;
            }
            next_arrival += 1;
        }
        sim.run_until(&mut world, target_sim, 50_000_000);
        if wall >= wall_duration {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    // Drain in-flight work (virtual time only).
    sim.run_until(&mut world, sim.now() + mins(5.0), 50_000_000);

    // Completion count straight from the API: a `limit=0` pagination probe
    // returns `total_entries` without materializing the page.
    let done = dispatch(
        &mut sim,
        &mut world,
        Method::Get,
        "/api/v1/dags/api_fanout/dagRuns?state=success&limit=0",
        None,
    );
    let completed = done.get("total_entries").and_then(|v| v.as_u64()).unwrap_or(0);

    // Latency: trigger time -> run completion, matched in order.
    let sink = collect_sink(world.db.read());
    let mut runs: Vec<_> = sink.runs.iter().filter(|r| r.success).collect();
    runs.sort_by_key(|r| r.run_id);
    let latencies: Vec<f64> = runs
        .iter()
        .zip(&request_starts)
        .map(|(r, (_, t0))| as_secs(r.last_end.saturating_sub(*t0)))
        .collect();
    let lat = Summary::of(&latencies);
    println!(
        "\ncompleted {completed} / {} requests ({rejected} rejected by the API)",
        request_starts.len()
    );
    println!("request latency [s, simulated]: {}", lat.line());
    println!(
        "throughput: {:.2} completed workflows / simulated minute",
        completed as f64 / (as_secs(sim.now() - start_sim) / 60.0)
    );

    // Control-plane health, as a client would see it.
    let health = dispatch(&mut sim, &mut world, Method::Get, "/api/v1/health", None);
    println!(
        "health: db_txns={} cdc_records={} run_states={}",
        health.get("db_txns").unwrap(),
        health.get("cdc_records").unwrap(),
        health.get("run_states").unwrap()
    );
    println!(
        "worker pool: peak {} concurrent lambda workers, {} cold starts",
        world.faas.stats(world.fns.worker).concurrent_peak,
        world.faas.stats(world.fns.worker).cold_starts
    );
}
