//! Cost explorer: interactive what-ifs over the paper's pricing model
//! (§6.4) — where does serverless Airflow stop being cheaper?
//!
//! ```sh
//! cargo run --release --example cost_explorer
//! ```

use sairflow::cost::{
    mwaa_fixed_daily, sairflow_breakdown, sairflow_fixed_daily, total, Pricing, Scenario,
};
use sairflow::dag::ExecKind;

fn scenario(tasks: u64, task_secs: f64, runs: u64, mwaa_extra_h: f64) -> Scenario {
    Scenario {
        name: "what-if",
        tasks,
        task_secs,
        dag_runs: runs,
        executor: ExecKind::Faas,
        worker_memory_mb: 340,
        mwaa_extra_worker_hours: mwaa_extra_h,
    }
}

fn main() {
    let p = Pricing::default();
    let s_fixed = sairflow_fixed_daily(true);
    let m_fixed = mwaa_fixed_daily(&p);
    println!("fixed daily: sAirflow {s_fixed:.2} $ vs MWAA {m_fixed:.2} $ (headline: halved)\n");

    // Sweep 1: task volume at fixed 60-s tasks. Where do variable costs
    // erase the fixed-cost advantage?
    println!("== sweep: tasks/day (60-s tasks, load fits the included MWAA worker) ==");
    println!("{:>10} {:>12} {:>12} {:>9}", "tasks/day", "sAirflow $", "MWAA $", "saving");
    for tasks in [100u64, 1_000, 5_000, 20_000, 50_000, 100_000, 200_000] {
        let s = scenario(tasks, 60.0, tasks / 100, 0.0);
        let s_total = s_fixed + total(&sairflow_breakdown(&s, &p));
        let m_total = m_fixed;
        println!(
            "{tasks:>10} {s_total:>12.2} {m_total:>12.2} {:>8.0}%",
            (1.0 - s_total / m_total) * 100.0
        );
    }
    println!("(break-even only at ~10^5 60-s tasks/day — idle efficiency dominates)\n");

    // Sweep 2: task duration at 1000 tasks/day.
    println!("== sweep: task duration (1000 tasks/day) ==");
    println!("{:>12} {:>12} {:>12} {:>12}", "task [s]", "FaaS exec $", "CaaS exec $", "cheaper");
    for secs in [10.0, 60.0, 300.0, 900.0, 3600.0] {
        let faas = scenario(1000, secs, 10, 0.0);
        let mut caas = scenario(1000, secs, 10, 0.0);
        caas.executor = ExecKind::Caas;
        let f = total(&sairflow_breakdown(&faas, &p));
        let c = total(&sairflow_breakdown(&caas, &p));
        let which = if secs > 900.0 {
            "CaaS (FaaS 15-min limit)"
        } else if f < c {
            "FaaS"
        } else {
            "CaaS"
        };
        println!("{secs:>12.0} {f:>12.3} {c:>12.3}   {which}");
    }
    println!();

    // Sweep 3: memory sizing of the worker function.
    println!("== sweep: worker memory (scenario 1: 1000 x 3-min tasks) ==");
    println!("{:>10} {:>10} {:>14}", "MB", "vCPU", "worker cost $");
    for mb in [256u32, 340, 512, 1024, 1769] {
        let mut s = scenario(1000, 180.0, 20, 0.0);
        s.worker_memory_mb = mb;
        let rows = sairflow_breakdown(&s, &p);
        let worker = rows
            .iter()
            .find(|r| r.component.contains("Worker"))
            .map(|r| r.cost)
            .unwrap_or(0.0);
        println!("{mb:>10} {:>10.2} {worker:>14.4}", mb as f64 / 1769.0);
    }
    println!("\n(paper: sAirflow total lower by 17-48%; fixed cost halved — Table 1)");
}
