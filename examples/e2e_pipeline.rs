//! End-to-end driver (the repository's headline validation, recorded in
//! EXPERIMENTS.md): run the paper's workloads through the full stack —
//!
//! 1. the **Alibaba-like 30-DAG benchmark** on both sAirflow and MWAA
//!    (the paper's realistic workload, Fig. 5);
//! 2. the **cold parallel sweep** reproducing the headline claim
//!    ("a cold system scales in seconds to 125 workers, reducing
//!    completion times by 2x-7x", §7);
//! 3. a **real data-plane pipeline**: workflow tasks whose payloads
//!    execute the AOT-compiled JAX/Pallas artifacts through the rust
//!    PJRT runtime (Python is not involved at run time).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use sairflow::dag::{DagSpec, ExecKind, Payload};
use sairflow::exp::{self, ExperimentSpec, SystemKind};
use sairflow::runtime::{default_artifacts_dir, Engine};
use sairflow::sairflow::{upload_dag, Config, World};
use sairflow::sim::time::mins;
use sairflow::util::json::Json;
use sairflow::workloads::{alibaba, synthetic::parallel_dag};

fn main() {
    let mut report = Json::obj();

    // ---- 1. headline: cold parallel sweep (2x-7x) ----------------------
    println!("== headline: cold parallel DAGs (p=10, T=30) ==");
    let mut ratios = Vec::new();
    for n in [16u32, 32, 64, 125] {
        let dags = vec![parallel_dag("p", n, 10.0, 30.0)];
        let sa = exp::run(&ExperimentSpec {
            label: format!("sairflow n={n}"),
            system: SystemKind::Sairflow,
            dags: dags.clone(),
            seed: 7,
            horizon: ExperimentSpec::paper_horizon(30.0),
            skip_first_run: false,
        });
        let mw = exp::run(&ExperimentSpec {
            label: format!("mwaa n={n}"),
            system: SystemKind::Mwaa { warm: false },
            dags,
            seed: 7,
            horizon: ExperimentSpec::paper_horizon(30.0),
            skip_first_run: false,
        });
        let ratio = mw.report.makespan.mean / sa.report.makespan.mean;
        println!(
            "n={n:<4} sAirflow {:>7.1} s | MWAA {:>7.1} s | {ratio:.2}x  (paper: 1.9x..7.2x)",
            sa.report.makespan.mean, mw.report.makespan.mean
        );
        report = report.set(
            &format!("cold_n{n}"),
            Json::obj()
                .set("sairflow_s", sa.report.makespan.mean)
                .set("mwaa_s", mw.report.makespan.mean)
                .set("ratio", ratio),
        );
        ratios.push(ratio);
    }
    assert!(ratios.windows(2).all(|w| w[1] > w[0] * 0.8), "ratios should grow with n");
    assert!(*ratios.last().unwrap() > 4.0, "n=125 speedup should be large");

    // ---- 2. Alibaba-like realistic workload ---------------------------
    println!("\n== Alibaba-like 30-DAG benchmark (medians over the set) ==");
    let set = alibaba::alibaba_set(20240501, 30);
    let mut s_mks = Vec::new();
    let mut m_mks = Vec::new();
    for d in &set {
        let t = alibaba::period_minutes_for(d);
        let spec = d.clone().every_minutes(t);
        let sa = exp::run(&ExperimentSpec {
            label: format!("sa {}", d.dag_id),
            system: SystemKind::Sairflow,
            dags: vec![spec.clone()],
            seed: 3,
            horizon: ExperimentSpec::paper_horizon(t),
            skip_first_run: false,
        });
        let mw = exp::run(&ExperimentSpec {
            label: format!("mw {}", d.dag_id),
            system: SystemKind::Mwaa { warm: true },
            dags: vec![spec],
            seed: 3,
            horizon: ExperimentSpec::paper_horizon(t),
            skip_first_run: false,
        });
        s_mks.push(sa.report.makespan.median);
        m_mks.push(mw.report.makespan.median);
    }
    let s_med = sairflow::util::stats::percentile(&s_mks, 0.5);
    let m_med = sairflow::util::stats::percentile(&m_mks, 0.5);
    println!(
        "median DAG makespan: sAirflow {s_med:.1} s vs MWAA {m_med:.1} s (paper: similar overall)"
    );
    report = report
        .set("alibaba_sairflow_median_s", s_med)
        .set("alibaba_mwaa_median_s", m_med);

    // ---- 3. real data plane: compute payloads via PJRT ----------------
    println!("\n== data-plane pipeline: PJRT compute payloads ==");
    match Engine::load_dir(&default_artifacts_dir()) {
        Err(e) => println!("(skipped: {e:#}; run `make artifacts`)"),
        Ok(engine) => {
            let mut dag = DagSpec::new("feature_pipeline").every_minutes(5.0);
            let ingest = dag.sleep_task("ingest", 2.0, &[]);
            let f1 = dag.add_task(
                "featurize_small",
                Payload::Compute { artifact: "pipeline_stage_r256".into(), iters: 20, rows: 256 },
                &[ingest],
                ExecKind::Faas,
            );
            let f2 = dag.add_task(
                "featurize_large",
                Payload::Compute { artifact: "pipeline_stage_r1024".into(), iters: 20, rows: 1024 },
                &[ingest],
                ExecKind::Faas,
            );
            let _train = dag.add_task(
                "train_step",
                Payload::Compute {
                    artifact: "pipeline_stage_grad_r256".into(),
                    iters: 5,
                    rows: 256,
                },
                &[f1, f2],
                ExecKind::Faas,
            );
            let mut world = World::new(Config::seeded(11));
            world.engine = Some(engine);
            let mut sim = world.sim();
            upload_dag(&mut sim, &mut world, &dag);
            sim.run_until(&mut world, mins(12.0), 10_000_000);
            let sink = exp::collect_sink(world.db.read());
            let rep = sairflow::metrics::MetricsReport::build("pjrt-pipeline", &sink, false);
            println!("{}", rep.text());
            let engine = world.engine.as_ref().unwrap();
            println!(
                "PJRT executions: {} (total wall {:.1} ms) — Python never ran",
                engine.stats.executions,
                engine.stats.wall_secs_total * 1e3
            );
            assert!(engine.stats.executions > 0, "compute payloads must execute");
            assert!(rep.failures == 0, "pipeline must succeed");
            report = report
                .set("pjrt_executions", engine.stats.executions)
                .set("pjrt_wall_ms", engine.stats.wall_secs_total * 1e3);
        }
    }

    match exp::save_report("e2e_pipeline", &report) {
        Ok(p) => println!("\nreport: {}", p.display()),
        Err(e) => eprintln!("report write failed: {e}"),
    }
    println!("E2E OK");
}
