//! Quickstart: define a workflow with the public API, deploy it on the
//! simulated serverless cloud, run it on sAirflow, and inspect the result.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sairflow::dag::{DagSpec, ExecKind, Payload};
use sairflow::exp;
use sairflow::metrics::gantt;
use sairflow::sairflow::{upload_dag, Config, World};
use sairflow::sim::time::{mins, secs};

fn main() {
    // 1. Author a workflow (what a user's DAG file expresses): a small
    //    ETL diamond — extract, two parallel transforms, load.
    let mut dag = DagSpec::new("etl_quickstart").every_minutes(5.0);
    let extract = dag.sleep_task("extract", 5.0, &[]);
    let t1 = dag.sleep_task("transform_users", 8.0, &[extract]);
    let t2 = dag.sleep_task("transform_orders", 6.0, &[extract]);
    let load = dag.add_task(
        "load",
        Payload::Sleep(secs(4.0)),
        &[t1, t2],
        ExecKind::Faas,
    );
    println!("workflow: {} tasks, load id {load}", dag.n_tasks());

    // 2. Deploy sAirflow (every Fig. 1 component) and upload the DAG file
    //    to blob storage — parsing, CDC, scheduling all flow from events.
    let mut world = World::new(Config::seeded(42));
    let mut sim = world.sim();
    upload_dag(&mut sim, &mut world, &dag);

    // 3. Let the simulated cloud run for 3 scheduled executions.
    sim.run_until(&mut world, mins(17.0), 10_000_000);

    // 4. Inspect: metrics straight from the metadata DB.
    let sink = exp::collect_sink(world.db.read());
    for run in &sink.runs {
        println!(
            "run {:>2}: makespan {:>6.2} s  success={}",
            run.run_id,
            run.makespan(),
            run.success
        );
    }
    let report = sairflow::metrics::MetricsReport::build("quickstart", &sink, false);
    println!("\n{}", report.text());

    if let Some(run) = sink.runs.last() {
        let tasks = sink.tasks_of(&run.dag_id, run.run_id);
        println!("\nGantt (last run):");
        println!("{}", gantt::render(&tasks, 80));
        println!("{}", gantt::listing(&tasks));
    }

    println!("control-plane events routed: {}", world.router.stats.events_in);
    println!("CDC records delivered      : {}", world.cdc.stats.records);
    println!("worker cold starts         : {}", world.faas.stats(world.fns.worker).cold_starts);
}
